"""Sampling tests: partition-aware vs random (the Figure-5 phenomenon) plus
hypothesis property tests on the estimator's invariants."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (SUM, Msgs, estimate_reduction_ratio, group_of,
                        num_groups_for_rate, partition_aware_sample,
                        random_sample, reduction_ratio)


def zipf_msgs(n=20000, keys=200, alpha=0.9, seed=0, workers=8):
    """Heavy-duplication workload split over workers.

    alpha ~0.9 is the rank exponent of web-graph in-degree (scale-free gamma
    ~2.1 -> rank exponent 1/(gamma-1) ~0.9) — the paper's PageRank-message
    regime, where no single destination dominates total traffic."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, keys + 1, dtype=np.float64)
    cdf = np.cumsum(ranks ** -alpha) / np.sum(ranks ** -alpha)
    ks = np.searchsorted(cdf, rng.random(n)).astype(np.int64)
    per = n // workers
    return [Msgs(ks[i * per:(i + 1) * per],
                 np.ones((per, 1))) for i in range(workers)]


def test_partition_aware_beats_random_at_low_rate():
    """Figure 5: at low rates, partition-aware stays near truth while random
    collapses to ~1.0 (a sparse sample almost never contains duplicates).

    The key space must be large relative to 1/rate so sampled groups hold
    enough keys to be traffic-representative (the paper's graphs have ~1e8
    keys; its 1e-4-rate groups still hold ~1e4 keys)."""
    shards = zipf_msgs(n=200000, keys=20000, workers=8)
    pooled = Msgs.concat(shards)
    truth = reduction_ratio(pooled, SUM)
    assert truth < 0.25                       # heavy-duplication regime

    for rate in (0.01, 0.002):
        pa = [partition_aware_sample(m, rate, seed=5) for m in shards]
        est_pa = estimate_reduction_ratio(pa, SUM)
        rnd = Msgs.concat([random_sample(m, rate, seed=5) for m in shards])
        est_rand = reduction_ratio(rnd, SUM)
        assert abs(est_pa - truth) < 0.15, (rate, est_pa, truth)
        assert est_rand > truth + 0.3, (rate, est_rand, truth)


def test_sample_overhead_scales_with_rate():
    shards = zipf_msgs()
    for rate in (0.1, 0.01):
        samp = [partition_aware_sample(m, rate, seed=1) for m in shards]
        frac = sum(s.n for s in samp) / sum(m.n for m in shards)
        assert frac < 4 * rate + 0.02, (rate, frac)


def test_group_of_consistency():
    """Same key -> same group (consistent hashing, Figure 4), groups cover."""
    keys = np.arange(5000, dtype=np.int64)
    g = group_of(keys, 100)
    g2 = group_of(keys, 100)
    assert np.array_equal(g, g2)
    assert np.unique(g).size == 100


# ---------------------------------------------------------------------------
# property-based tests (hypothesis)
# ---------------------------------------------------------------------------

@given(rate=st.floats(0.0001, 1.0))
def test_num_groups_positive(rate):
    s = num_groups_for_rate(rate)
    assert s >= 1
    assert abs(1.0 / s - rate) <= rate        # rate ~ 1/s up to rounding


@given(keys=st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=300),
       rate=st.sampled_from([1.0, 0.5, 0.1, 0.03]),
       seed=st.integers(0, 10))
@settings(max_examples=60, deadline=None)
def test_sample_is_destination_closed(keys, rate, seed):
    """Property: the sample contains EVERY message of the chosen group and
    NONE of any other group — the closure partition-aware sampling is built on."""
    ks = np.asarray(keys, np.int64)
    msgs = Msgs(ks, np.ones((len(keys), 1)))
    samp = partition_aware_sample(msgs, rate, seed=seed)
    s = num_groups_for_rate(rate)
    groups = group_of(ks, s)
    sampled_groups = np.unique(group_of(samp.keys, s)) if samp.n else []
    assert len(sampled_groups) <= 1
    if samp.n:
        j = sampled_groups[0]
        assert samp.n == int(np.sum(groups == j))


@given(keys=st.lists(st.integers(0, 50), min_size=1, max_size=200))
@settings(max_examples=60, deadline=None)
def test_reduction_ratio_bounds(keys):
    """Property: ratio in (0, 1]; equals |unique|/|keys| for SUM."""
    msgs = Msgs(np.asarray(keys, np.int64), np.ones((len(keys), 1)))
    r = reduction_ratio(msgs, SUM)
    assert 0 < r <= 1.0
    assert r == pytest.approx(np.unique(keys).size / len(keys))


@given(seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_estimator_unbiased_over_seeds(seed):
    """Pooled-shard estimation sees cross-worker duplicates; accuracy holds
    across group choices when groups hold >=100 keys."""
    shards = zipf_msgs(n=20000, keys=2000, seed=seed % 5, workers=4)
    est = estimate_reduction_ratio(
        [partition_aware_sample(m, 0.05, seed=seed) for m in shards], SUM)
    truth = reduction_ratio(Msgs.concat(shards), SUM)
    assert abs(est - truth) < 0.25
