"""Sampling tests: partition-aware vs random (the Figure-5 phenomenon) plus
hypothesis property tests on the estimator's invariants, the empty-group
fallback, and the Msgs.concat width fix."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (SUM, Msgs, estimate_reduction_ratio,
                        estimate_reduction_ratio_with_fallback, group_of,
                        num_groups_for_rate, partition_aware_sample,
                        random_sample, reduction_ratio, sample_with_fallback)


def zipf_msgs(n=20000, keys=200, alpha=0.9, seed=0, workers=8):
    """Heavy-duplication workload split over workers.

    alpha ~0.9 is the rank exponent of web-graph in-degree (scale-free gamma
    ~2.1 -> rank exponent 1/(gamma-1) ~0.9) — the paper's PageRank-message
    regime, where no single destination dominates total traffic."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, keys + 1, dtype=np.float64)
    cdf = np.cumsum(ranks ** -alpha) / np.sum(ranks ** -alpha)
    ks = np.searchsorted(cdf, rng.random(n)).astype(np.int64)
    per = n // workers
    return [Msgs(ks[i * per:(i + 1) * per],
                 np.ones((per, 1))) for i in range(workers)]


def test_partition_aware_beats_random_at_low_rate():
    """Figure 5: at low rates, partition-aware stays near truth while random
    collapses to ~1.0 (a sparse sample almost never contains duplicates).

    The key space must be large relative to 1/rate so sampled groups hold
    enough keys to be traffic-representative (the paper's graphs have ~1e8
    keys; its 1e-4-rate groups still hold ~1e4 keys)."""
    shards = zipf_msgs(n=200000, keys=20000, workers=8)
    pooled = Msgs.concat(shards)
    truth = reduction_ratio(pooled, SUM)
    assert truth < 0.25                       # heavy-duplication regime

    for rate in (0.01, 0.002):
        pa = [partition_aware_sample(m, rate, seed=5) for m in shards]
        est_pa = estimate_reduction_ratio(pa, SUM)
        rnd = Msgs.concat([random_sample(m, rate, seed=5) for m in shards])
        est_rand = reduction_ratio(rnd, SUM)
        assert abs(est_pa - truth) < 0.15, (rate, est_pa, truth)
        assert est_rand > truth + 0.3, (rate, est_rand, truth)


def test_sample_overhead_scales_with_rate():
    shards = zipf_msgs()
    for rate in (0.1, 0.01):
        samp = [partition_aware_sample(m, rate, seed=1) for m in shards]
        frac = sum(s.n for s in samp) / sum(m.n for m in shards)
        assert frac < 4 * rate + 0.02, (rate, frac)


def test_group_of_consistency():
    """Same key -> same group (consistent hashing, Figure 4), groups cover."""
    keys = np.arange(5000, dtype=np.int64)
    g = group_of(keys, 100)
    g2 = group_of(keys, 100)
    assert np.array_equal(g, g2)
    assert np.unique(g).size == 100


# ---------------------------------------------------------------------------
# Msgs.concat width propagation (the empty-batch byte-accounting fix)
# ---------------------------------------------------------------------------

def test_concat_preserves_width_when_all_inputs_empty():
    assert Msgs.concat([Msgs.empty(width=4)]).width == 4
    assert Msgs.concat([Msgs.empty(width=2), Msgs.empty(width=5)]).width == 5
    assert Msgs.concat([None, Msgs.empty(width=3)]).width == 3
    assert Msgs.concat([]).width == 1                       # nothing to preserve
    # an empty wide result charges per column, like the batches it stands for
    assert Msgs.concat([Msgs.empty(width=4)]).nbytes == 0
    wide = Msgs(np.array([1, 2]), np.ones((2, 4)))
    # ... and concats with real wide batches downstream instead of raising
    again = Msgs.concat([Msgs.concat([Msgs.empty(width=4)]), wide])
    assert again.width == 4 and again.n == 2


def test_concat_nonempty_unchanged():
    a = Msgs(np.array([1, 2]), np.ones((2, 3)))
    b = Msgs(np.array([3]), np.full((1, 3), 2.0))
    out = Msgs.concat([a, Msgs.empty(width=3), b])
    assert out.n == 3 and out.width == 3
    np.testing.assert_array_equal(out.keys, [1, 2, 3])


# ---------------------------------------------------------------------------
# empty-pooled-sample fallback (bounded resampling of further hash groups)
# ---------------------------------------------------------------------------

def _msgs_missing_primary_group(rate=0.25, max_seed=200):
    """A workload whose keys all avoid the primary sampled group for some
    seed: found deterministically by scanning seeds."""
    keys = np.full(64, 17, dtype=np.int64)      # one key -> one group occupied
    msgs = Msgs(keys, np.ones((64, 1)))
    s = num_groups_for_rate(rate)
    for seed in range(max_seed):
        if partition_aware_sample(msgs, rate, seed=seed).n == 0:
            return msgs, rate, seed, s
    raise AssertionError("no seed misses the occupied group; widen the scan")


def test_fallback_recovers_from_empty_primary_group():
    msgs, rate, seed, s = _msgs_missing_primary_group()
    # the old estimator: empty pooled sample -> r^=1.0, stage rejected
    assert estimate_reduction_ratio(
        [partition_aware_sample(msgs, rate, seed=seed)], SUM) == 1.0
    # the fallback visits further groups until one holds the data
    samples = sample_with_fallback(msgs, rate, seed=seed)
    assert len(samples) > 1 and samples[0].n == 0 and samples[-1].n > 0
    r, attempts = estimate_reduction_ratio_with_fallback([samples], SUM)
    assert attempts == len(samples) - 1 >= 1
    assert r == pytest.approx(reduction_ratio(msgs, SUM))   # 1/64: heavy dup


def test_fallback_noop_when_primary_group_holds_data():
    shards = zipf_msgs(n=20000, keys=2000, workers=4)
    lists = [sample_with_fallback(m, 0.05, seed=3) for m in shards]
    assert all(len(sl) == 1 for sl in lists)                # no retries drawn
    r, attempts = estimate_reduction_ratio_with_fallback(lists, SUM)
    assert attempts == 0
    assert r == estimate_reduction_ratio([sl[0] for sl in lists], SUM)


def test_fallback_gives_up_after_bounded_retries():
    empty = Msgs.empty()
    samples = sample_with_fallback(empty, 0.25, seed=0, max_retries=3)
    assert len(samples) == 4 and all(s.n == 0 for s in samples)
    r, attempts = estimate_reduction_ratio_with_fallback([samples], SUM)
    assert r == 1.0 and attempts == 3


def test_fallback_recorded_in_eff_cost_decision():
    """End to end: a shuffle whose primary sampled group is empty still finds
    the beneficial combine, and the verdict records the fallback attempts."""
    from repro.core import TeShuService, datacenter
    topo = datacenter(2, 2, 2, oversubscription=10.0)
    nw = topo.num_workers
    # 64 distinct keys shared by every worker: locally unique (the template's
    # local combine removes nothing), fully duplicated across workers — but at
    # rate 0.02 they occupy only a fraction of the 50 hash groups, so a seed
    # whose primary group is empty exists and is found deterministically
    keys = np.arange(100, 164, dtype=np.int64)
    rate = 0.02
    msgs = Msgs(keys, np.ones((keys.size, 1)))
    seed = next(sd for sd in range(300)
                if partition_aware_sample(msgs, rate, seed=sd).n == 0)
    bufs = {w: Msgs(keys.copy(), np.ones((keys.size, 8))) for w in range(nw)}
    svc = TeShuService(topo)
    # SAMP seeds with seed + shuffle_id (=1 on the service's first call)
    res = svc.shuffle("network_aware", bufs, list(range(nw)), list(range(nw)),
                      comb_fn=SUM, rate=rate, seed=seed - 1)
    decisions = dict(res.decisions)
    assert decisions and all(ec.sample_attempts >= 1
                             for ec in decisions.values())
    assert all(ec.beneficial for ec in decisions.values()), \
        "empty primary group must not silently reject the combine stage"


# ---------------------------------------------------------------------------
# property-based tests (hypothesis)
# ---------------------------------------------------------------------------

@given(rate=st.floats(0.0001, 1.0))
def test_num_groups_positive(rate):
    s = num_groups_for_rate(rate)
    assert s >= 1
    assert abs(1.0 / s - rate) <= rate        # rate ~ 1/s up to rounding


@given(keys=st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=300),
       rate=st.sampled_from([1.0, 0.5, 0.1, 0.03]),
       seed=st.integers(0, 10))
@settings(max_examples=60, deadline=None)
def test_sample_is_destination_closed(keys, rate, seed):
    """Property: the sample contains EVERY message of the chosen group and
    NONE of any other group — the closure partition-aware sampling is built on."""
    ks = np.asarray(keys, np.int64)
    msgs = Msgs(ks, np.ones((len(keys), 1)))
    samp = partition_aware_sample(msgs, rate, seed=seed)
    s = num_groups_for_rate(rate)
    groups = group_of(ks, s)
    sampled_groups = np.unique(group_of(samp.keys, s)) if samp.n else []
    assert len(sampled_groups) <= 1
    if samp.n:
        j = sampled_groups[0]
        assert samp.n == int(np.sum(groups == j))


@given(keys=st.lists(st.integers(0, 50), min_size=1, max_size=200))
@settings(max_examples=60, deadline=None)
def test_reduction_ratio_bounds(keys):
    """Property: ratio in (0, 1]; equals |unique|/|keys| for SUM."""
    msgs = Msgs(np.asarray(keys, np.int64), np.ones((len(keys), 1)))
    r = reduction_ratio(msgs, SUM)
    assert 0 < r <= 1.0
    assert r == pytest.approx(np.unique(keys).size / len(keys))


@given(seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_estimator_unbiased_over_seeds(seed):
    """Pooled-shard estimation sees cross-worker duplicates; accuracy holds
    across group choices when groups hold >=100 keys."""
    shards = zipf_msgs(n=20000, keys=2000, seed=seed % 5, workers=4)
    est = estimate_reduction_ratio(
        [partition_aware_sample(m, 0.05, seed=seed) for m in shards], SUM)
    truth = reduction_ratio(Msgs.concat(shards), SUM)
    assert abs(est - truth) < 0.25


@given(alpha=st.sampled_from([0.7, 0.9, 1.1]), seed=st.integers(0, 30))
@settings(max_examples=40, deadline=None)
def test_partition_aware_bias_property_on_skewed_keys(alpha, seed):
    """Property (Figure 5, across skew exponents): on Zipf-skewed keys a
    pooled multi-group partition-aware estimate tracks truth, while random
    tuple sampling at the SAME total coverage stays biased upward.  Pooling
    several complete groups is the fair comparison at heavy skew: a single
    group's ratio has high *variance* there (one mega-hot key dominates its
    group), but the bias is zero — random sampling's error is structural and
    no amount of extra coverage at the same rate removes it."""
    rate, groups = 0.01, 5
    shards = zipf_msgs(n=100000, keys=20000, alpha=alpha, seed=seed % 7,
                       workers=4)
    truth = reduction_ratio(Msgs.concat(shards), SUM)
    pooled = [partition_aware_sample(m, rate, seed=seed, attempt=a)
              for m in shards for a in range(groups)]
    est_pa = estimate_reduction_ratio(pooled, SUM)
    est_rand = reduction_ratio(Msgs.concat(
        [random_sample(m, rate * groups, seed=seed) for m in shards]), SUM)
    assert abs(est_pa - truth) < 0.25, (est_pa, truth)
    assert est_rand > truth + 0.08, (est_rand, truth)


@given(keys=st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=200),
       rate=st.sampled_from([0.5, 0.25, 0.1]),
       seed=st.integers(0, 20))
@settings(max_examples=60, deadline=None)
def test_fallback_estimate_properties(keys, rate, seed):
    """Properties of the empty-group fallback: the sample list is empty
    batches followed by at most one non-empty one; each attempt is closed
    over a single group; and on non-empty data the estimator either uses a
    complete group (ratio in (0, 1], exact |unique|/|n| of that group for
    SUM) or exhausts its bounded retries."""
    ks = np.asarray(keys, np.int64)
    msgs = Msgs(ks, np.ones((len(keys), 1)))
    samples = sample_with_fallback(msgs, rate, seed=seed)
    s = num_groups_for_rate(rate)
    # primary + bounded retries, never revisiting a group (<= s - 1 retries)
    assert 1 <= len(samples) <= 1 + min(3, s - 1)
    assert all(b.n == 0 for b in samples[:-1])
    for b in samples:
        if b.n:
            groups = np.unique(group_of(b.keys, s))
            assert groups.size == 1          # closure: one whole group
    r, attempts = estimate_reduction_ratio_with_fallback([samples], SUM)
    assert 0 < r <= 1.0
    assert attempts == len(samples) - 1
    if samples[-1].n:
        assert r == pytest.approx(
            np.unique(samples[-1].keys).size / samples[-1].n)


@given(widths=st.lists(st.integers(1, 8), min_size=1, max_size=5))
@settings(max_examples=40, deadline=None)
def test_concat_width_property(widths):
    """Property: concat of empties carries the widest input; appending one
    real batch of that width always concatenates cleanly."""
    empties = [Msgs.empty(width=w) for w in widths]
    out = Msgs.concat(empties)
    assert out.n == 0 and out.width == max(widths)
    real = Msgs(np.arange(3), np.ones((3, max(widths))))
    assert Msgs.concat([out, real]).n == 3
