"""Streaming shuffle: chunk-pipelined epochs (ISSUE 4 acceptance).

The contract under test: a streamed shuffle — senders PART/SEND fixed-budget
chunks, receivers incrementally combine into a running accumulator, an
end-of-stream rendezvous replaces the barrier — is *byte-identical* to the
barrier path for every streamable template, on both executors, across chunk
boundaries (chunk > data, one-row chunks, ragged last chunk), including under
a mid-chunk worker kill recovered at chunk granularity; and pipelined modelled
time beats the barrier on data-dominated multi-stage workloads.
"""
import numpy as np
import pytest

from conformance import WORKERS, assert_identical as _assert_identical, \
    copy_bufs as _copy, make_bufs, make_topology
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import (MIN, SUM, CheckpointStore, ChunkPlan, CostLedger, Msgs,
                        TeShuService, adaptive_sketch_capacity, datacenter,
                        eff_cost_from_ratio, local_skew_stats,
                        stats_signature)
from repro.core.messages import HASH_PART
from repro.core.skew import (HOT_KEY_FRACTION, MAX_SKETCH_CAPACITY,
                             MIN_SKETCH_CAPACITY, HeavyHitterSketch)

STREAMABLE = ("vanilla_push", "vanilla_pull", "coordinated", "network_aware")


def _topo(**kw):
    # this suite models a fatter combine engine on a thinner core fabric
    kw.setdefault("oversubscription", 10.0)
    kw.setdefault("combine_bytes_per_s", 64e9)
    return make_topology(**kw)


def _bufs(n=400, key_space=64, width=2, seed=7):
    return make_bufs(WORKERS, "uniform", n=n, key_space=key_space,
                     width=width, seed=seed)


# ---------------------------------------------------------------------------
# ChunkPlan
# ---------------------------------------------------------------------------

def test_chunk_plan_slicing_covers_buffer_in_order():
    cp = ChunkPlan(chunk_bytes=24 * 7)            # 7 rows of width 2
    m = Msgs(np.arange(100), np.arange(200.0).reshape(100, 2))
    assert cp.rows_per_chunk(2) == 7
    assert cp.nchunks(m) == 15                    # ragged last chunk (2 rows)
    got = Msgs.concat(list(cp.chunks(m)))
    np.testing.assert_array_equal(got.keys, m.keys)
    np.testing.assert_array_equal(got.vals, m.vals)
    assert cp.chunk(m, 14).n == 2


def test_chunk_plan_empty_buffer_keeps_width():
    cp = ChunkPlan(chunk_bytes=1024)
    empty = Msgs.empty(width=3)
    assert cp.nchunks(empty) == 1                 # one empty chunk, width intact
    assert cp.chunk(empty, 0).width == 3


def test_chunk_plan_extremes_and_validation():
    m = Msgs(np.arange(10), np.ones((10, 1)))
    assert ChunkPlan(chunk_bytes=10**9).nchunks(m) == 1     # chunk > data
    assert ChunkPlan(chunk_bytes=1).rows_per_chunk(1) == 1  # one-row chunks
    assert ChunkPlan(chunk_bytes=1).nchunks(m) == 10
    with pytest.raises(ValueError):
        ChunkPlan(chunk_bytes=0)
    with pytest.raises(ValueError):
        ChunkPlan(max_inflight=0)
    sig = ChunkPlan(chunk_bytes=64 * 1024, max_inflight=4).signature()
    assert sig[0] == "stream" and len(sig) == 3


# ---------------------------------------------------------------------------
# The foundation: incremental combine is an exact continuation of the fold
# ---------------------------------------------------------------------------

def _fold_matches_oneshot(keys, vals, chunk_rows, comb):
    msgs = Msgs(keys, vals)
    oneshot = comb(msgs)
    acc = None
    for c in range(0, msgs.n, chunk_rows):
        piece = Msgs(keys[c:c + chunk_rows], vals[c:c + chunk_rows])
        batch = piece if acc is None else Msgs.concat([acc, piece])
        acc = comb(batch)
    np.testing.assert_array_equal(oneshot.keys, acc.keys)
    np.testing.assert_array_equal(oneshot.vals, acc.vals)


@pytest.mark.parametrize("comb", [SUM, MIN])
@pytest.mark.parametrize("chunk_rows", [1, 7, 1000])
def test_incremental_combine_bit_exact(comb, chunk_rows):
    rng = np.random.default_rng(0)
    _fold_matches_oneshot(rng.integers(0, 37, 800),
                          rng.random((800, 3)) * 1e3 - 500, chunk_rows, comb)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 50), st.integers(1, 200), st.integers(0, 2**31))
def test_incremental_combine_bit_exact_property(chunk_rows, n, seed):
    rng = np.random.default_rng(seed)
    _fold_matches_oneshot(rng.integers(0, 11, n),
                          rng.standard_normal((n, 2)) * 10.0**rng.integers(-8, 8),
                          chunk_rows, SUM)


# ---------------------------------------------------------------------------
# Ledger: pipelined lanes
# ---------------------------------------------------------------------------

def test_stream_lanes_pipeline_bound():
    topo = _topo()
    led = CostLedger(topo)
    bw = topo.levels[2].bw_bytes_per_s
    cbw = topo.levels[0].combine_bytes_per_s
    for c in range(4):                    # worker 0: 4 transfer + 4 combine chunks
        led.charge_transfer(0, 2, 1000, dst=1, chunk=c)
        led.charge_combine(0, 4000, chunk=c)
    x, comb = 4 * 1000 / bw, 4 * 4000 / cbw
    expect = max(x, comb) + min(x, comb) / 4 + topo.levels[2].latency_s
    assert led.modelled_time() == pytest.approx(expect)
    led.end_stream()
    assert led.modelled_time() == pytest.approx(expect)   # folded, lanes clear
    led.end_stream()                                      # idempotent no-op
    assert led.modelled_time() == pytest.approx(expect)
    assert led.bytes_at_level(2) == 4000                  # byte totals unchanged


def test_stream_single_chunk_degenerates_to_barrier_sum():
    led = CostLedger(_topo())
    led.charge_transfer(0, 2, 8000, dst=1, chunk=0)
    led.charge_combine(0, 8000, chunk=0)
    led_b = CostLedger(_topo())
    led_b.charge_transfer(0, 2, 8000, dst=1)
    led_b.charge_combine(0, 8000)
    assert led.modelled_time() == pytest.approx(led_b.modelled_time())


def test_recv_imbalance_from_ledger():
    led = CostLedger(_topo())
    assert led.recv_imbalance([0, 1]) == 1.0              # no traffic yet
    led.charge_transfer(0, 2, 3000, dst=1)
    led.charge_transfer(0, 2, 1000, dst=2)
    assert led.recv_imbalance([1, 2]) == pytest.approx(3000 / 2000)
    assert led.recv_imbalance([5]) == 1.0


# ---------------------------------------------------------------------------
# Byte-identity: streamed == barrier, every streamable template, both
# executors, across chunk-size boundaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("template", STREAMABLE)
def test_streamed_byte_identical_to_barrier(template):
    bufs = _bufs()
    barrier = TeShuService(_topo()).shuffle(template, _copy(bufs), WORKERS,
                                            WORKERS, comb_fn=SUM, rate=0.05)
    assert not barrier.streamed
    # chunk budgets: many ragged chunks / one-row chunks / chunk > data
    for chunk_bytes in (1500, 24, 10**9):
        svc = TeShuService(_topo(), streaming="auto", chunk_bytes=chunk_bytes)
        fresh = svc.shuffle(template, _copy(bufs), WORKERS, WORKERS,
                            comb_fn=SUM, rate=0.05, execution="threaded")
        assert fresh.streamed and not fresh.vectorized
        _assert_identical(barrier.bufs, fresh.bufs)
        hit = svc.shuffle(template, _copy(bufs), WORKERS, WORKERS,
                          comb_fn=SUM, rate=0.05)
        assert hit.streamed and hit.cached and hit.vectorized
        _assert_identical(barrier.bufs, hit.bufs)


@pytest.mark.parametrize("comb", [None, MIN])
def test_streamed_byte_identical_other_combiners(comb):
    bufs = _bufs(n=240)
    barrier = TeShuService(_topo()).shuffle("vanilla_push", _copy(bufs),
                                            WORKERS, WORKERS, comb_fn=comb)
    svc = TeShuService(_topo(), streaming="auto", chunk_bytes=600)
    for _ in range(2):                                    # fresh, then cached
        res = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                          comb_fn=comb)
        assert res.streamed
        _assert_identical(barrier.bufs, res.bufs)


def test_streamed_byte_identical_deterministic_sweep():
    """In-container stand-in for the hypothesis property: random workloads x
    random chunk budgets, exact byte equality against the barrier path."""
    rng = np.random.default_rng(123)
    for trial in range(6):
        n = int(rng.integers(1, 300))
        ks = int(rng.integers(1, 200))
        width = int(rng.integers(1, 4))
        chunk_bytes = int(rng.integers(1, 4000))
        bufs = {w: Msgs(rng.integers(0, ks, n),
                        rng.standard_normal((n, width)) * 1e6)
                for w in WORKERS}
        barrier = TeShuService(_topo()).shuffle("vanilla_push", _copy(bufs),
                                                WORKERS, WORKERS, comb_fn=SUM)
        svc = TeShuService(_topo(), streaming="auto", chunk_bytes=chunk_bytes)
        res = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                          comb_fn=SUM)
        assert res.streamed
        _assert_identical(barrier.bufs, res.bufs)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 150), st.integers(1, 100), st.integers(1, 2500),
       st.integers(0, 2**31), st.sampled_from(STREAMABLE))
def test_streamed_byte_identical_property(n, key_space, chunk_bytes, seed,
                                          template):
    rng = np.random.default_rng(seed)
    bufs = {w: Msgs(rng.integers(0, key_space, n), rng.random((n, 1)))
            for w in WORKERS}
    barrier = TeShuService(_topo()).shuffle(template, _copy(bufs), WORKERS,
                                            WORKERS, comb_fn=SUM, rate=0.05)
    svc = TeShuService(_topo(), streaming="auto", chunk_bytes=chunk_bytes)
    res = svc.shuffle(template, _copy(bufs), WORKERS, WORKERS, comb_fn=SUM,
                      rate=0.05)
    assert res.streamed
    _assert_identical(barrier.bufs, res.bufs)


def test_streamed_byte_totals_match_barrier():
    """The streamed data plane moves exactly the barrier's bytes — chunking
    changes *when* bytes are charged (pipelined lanes), never how many."""
    bufs = _bufs()
    off = TeShuService(_topo())
    off.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS, comb_fn=SUM)
    on = TeShuService(_topo(), streaming="auto", chunk_bytes=800)
    on.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS, comb_fn=SUM)
    a, b = off.stats(), on.stats()
    assert a["total_bytes"] == b["total_bytes"]
    assert a["bytes_per_level"] == b["bytes_per_level"]
    assert a["recv_bytes_per_worker"] == b["recv_bytes_per_worker"]


# ---------------------------------------------------------------------------
# Plan cache integration
# ---------------------------------------------------------------------------

def test_streaming_keys_and_freezes_chunk_plan():
    bufs = _bufs(n=200)
    svc = TeShuService(_topo(), streaming="auto", chunk_bytes=1024)
    svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS, comb_fn=SUM)
    res = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                      comb_fn=SUM)
    assert res.streamed and res.cached
    (key, plan), = svc.plan_cache.scan()
    assert plan.stream == ChunkPlan(chunk_bytes=1024)
    # a barrier call on the same workload must not alias the streamed plan
    res_off = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                          comb_fn=SUM, streaming="off")
    assert not res_off.streamed and not res_off.cached
    assert len(svc.plan_cache) == 2
    _assert_identical(res.bufs, res_off.bufs)


def test_signature_separates_streaming_modes_and_buckets():
    bufs = _bufs(n=100)
    base = stats_signature(bufs, HASH_PART, SUM, 0.01)
    on = stats_signature(bufs, HASH_PART, SUM, 0.01, streaming="auto",
                         stream=ChunkPlan(chunk_bytes=1024))
    assert base != on
    assert stats_signature(bufs, HASH_PART, SUM, 0.01, streaming="auto",
                           stream=ChunkPlan(chunk_bytes=64 * 1024)) != on
    # within a log2 bucket the policy aliases (byte-identity makes it safe)
    assert stats_signature(bufs, HASH_PART, SUM, 0.01, streaming="auto",
                           stream=ChunkPlan(chunk_bytes=1030)) == on
    # counts stay last (plan repair's participant-subset contract)
    assert isinstance(on[-1], tuple) and isinstance(on[-1][0], tuple)


def test_non_streamable_template_resolves_to_off():
    bufs = _bufs(n=144, seed=3)
    workers = list(range(4))          # two_level needs a square grid
    b4 = {w: bufs[w] for w in workers}
    svc = TeShuService(_topo(), streaming="auto", chunk_bytes=512)
    for template in ("bruck", "two_level"):
        res = svc.shuffle(template, _copy(b4), workers, workers, comb_fn=SUM)
        assert not res.streamed
        ref = TeShuService(_topo()).shuffle(template, _copy(b4), workers,
                                            workers, comb_fn=SUM)
        _assert_identical(ref.bufs, res.bufs)


# ---------------------------------------------------------------------------
# Interaction with skew rebalancing
# ---------------------------------------------------------------------------

def test_streaming_defers_to_skew_rebalance():
    """A triggered hot-key scatter is positional over the whole buffer, so the
    run falls back to barrier programs — uniformly, on both executors — and
    stays byte-identical to the balance-only path."""
    rng = np.random.default_rng(11)
    ranks = np.arange(1, 400)
    cdf = np.cumsum(ranks**-1.2) / np.sum(ranks**-1.2)
    zipf = {w: Msgs(np.searchsorted(cdf, rng.random(3000)).astype(np.int64),
                    rng.random((3000, 1))) for w in WORKERS}
    ref = TeShuService(_topo(), balance="auto").shuffle(
        "vanilla_push", _copy(zipf), WORKERS, WORKERS, comb_fn=SUM)
    dec = dict(ref.decisions).get("rebalance")
    assert dec is not None and dec.triggered
    svc = TeShuService(_topo(), balance="auto", streaming="auto",
                       chunk_bytes=2048)
    fresh = svc.shuffle("vanilla_push", _copy(zipf), WORKERS, WORKERS,
                        comb_fn=SUM)
    assert not fresh.streamed                  # deferred to the barrier model
    _assert_identical(ref.bufs, fresh.bufs)
    hit = svc.shuffle("vanilla_push", _copy(zipf), WORKERS, WORKERS,
                      comb_fn=SUM)
    assert hit.cached and not hit.streamed
    _assert_identical(ref.bufs, hit.bufs)


# ---------------------------------------------------------------------------
# Chunk-granular recovery: mid-chunk worker kill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("execution", ["threaded", "auto"])
def test_mid_chunk_kill_recovers_byte_identical(execution):
    bufs = _bufs(n=600)
    ref = TeShuService(_topo()).shuffle("vanilla_push", _copy(bufs), WORKERS,
                                        WORKERS, comb_fn=SUM)
    svc = TeShuService(_topo(), execution=execution, streaming="auto",
                       chunk_bytes=2048, resilience="recover")
    svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS, comb_fn=SUM)
    svc.inject_fault(3, after_chunk=2)
    res = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                      comb_fn=SUM)
    assert res.attempts == 2 and res.streamed
    _assert_identical(ref.bufs, res.bufs)
    # chunk granularity: the retry resumed folds from a nonzero stream cursor
    resumes = [r.stage for r in svc.manager.records(kind="stage")
               if r.stage and r.stage.startswith("stream-resume:global:")]
    assert resumes and any(not s.endswith(":0:0") for s in resumes)


@pytest.mark.parametrize("execution", ["threaded", "auto"])
def test_mid_chunk_kill_multi_stage_template(execution):
    rng = np.random.default_rng(9)
    bufs = {w: Msgs(np.repeat(rng.integers(0, 256, 60), 10),
                    rng.random((600, 1))) for w in WORKERS}
    ref = TeShuService(_topo()).shuffle("network_aware", _copy(bufs), WORKERS,
                                        WORKERS, comb_fn=SUM, rate=0.05)
    svc = TeShuService(_topo(), execution=execution, streaming="auto",
                       chunk_bytes=512, resilience="recover")
    svc.shuffle("network_aware", _copy(bufs), WORKERS, WORKERS, comb_fn=SUM,
                rate=0.05)
    svc.inject_fault(5, after_chunk=1)
    res = svc.shuffle("network_aware", _copy(bufs), WORKERS, WORKERS,
                      comb_fn=SUM, rate=0.05)
    assert res.attempts == 2 and res.streamed
    _assert_identical(ref.bufs, res.bufs)


def test_stream_checkpoint_store_roundtrip():
    store = CheckpointStore()
    acc = Msgs(np.arange(5), np.ones((5, 1)))
    store.save_stream(1, 3, "global", 2, 4, 960, acc)
    ck = store.load_stream(1, 3, "global")
    assert (ck.peer_idx, ck.folded, ck.pre_bytes) == (2, 4, 960)
    ck.acc.vals[:] = -1                       # copies: no aliasing
    assert store.load_stream(1, 3, "global").acc.vals.sum() == 5
    assert store.load_stream(1, 3, "server") is None
    assert store.stats()["stream_checkpoints"] == 1
    store.clear(1)
    assert store.load_stream(1, 3, "global") is None


# ---------------------------------------------------------------------------
# Modelled time: pipelined <= barrier, strictly below when data-dominated
# ---------------------------------------------------------------------------

def _modelled(template, streaming, bufs, topo, **kw):
    svc = TeShuService(topo, streaming=streaming, **kw)
    W = list(range(topo.num_workers))
    svc.shuffle(template, _copy(bufs), W, W, comb_fn=SUM, rate=0.02)  # warm
    svc.reset_stats()
    res = svc.shuffle(template, _copy(bufs), W, W, comb_fn=SUM, rate=0.02)
    assert res.streamed == (streaming == "auto")
    return svc.stats()["modelled_time_s"]


@pytest.mark.parametrize("template", ["vanilla_push", "network_aware"])
def test_pipelined_modelled_time_beats_barrier(template):
    # every worker holds the same key pool permuted: no intra-worker dedup
    # (the exchanges stay data-heavy) but heavy cross-worker duplication
    # (hierarchical combining stays beneficial — both stages trigger)
    topo = datacenter(4, 2, 2, oversubscription=8.0)
    rng = np.random.default_rng(3)
    pool = np.arange(30000)
    bufs = {w: Msgs(rng.permutation(pool), rng.random((30000, 1)))
            for w in range(topo.num_workers)}
    t_off = _modelled(template, "off", bufs, topo)
    t_on = _modelled(template, "auto", bufs, topo, chunk_bytes=64 * 1024)
    assert t_on < t_off, (template, t_on, t_off)


def test_single_chunk_stream_no_worse_than_barrier():
    """chunk > data: one chunk per stream degenerates the pipeline bound to
    the BSP sum — streaming must never cost data time (latency-scale epoch
    bookkeeping aside)."""
    bufs = _bufs(n=200)
    topo = _topo()
    t_off = _modelled("vanilla_push", "off", bufs, topo)
    t_on = _modelled("vanilla_push", "auto", bufs, topo, chunk_bytes=10**9)
    assert t_on == pytest.approx(t_off, rel=0.05)


# ---------------------------------------------------------------------------
# feed()/drain() continuous ingest
# ---------------------------------------------------------------------------

def test_feed_drain_matches_barrier_totals():
    topo = _topo()
    svc = TeShuService(topo, streaming="auto", chunk_bytes=512)
    sess = svc.open_stream("vanilla_push", WORKERS, WORKERS, comb_fn=SUM)
    rng = np.random.default_rng(2)
    feeds = [{w: Msgs(rng.integers(0, 40, 90), 1.0 * rng.integers(0, 100, (90, 1)))
              for w in WORKERS} for _ in range(3)]
    for f in feeds:
        assert sess.feed(_copy(f)) > 0
    out = sess.drain()
    assert out["chunks"] == sess.chunks_fed and out["rows"] == 3 * 8 * 90
    assert out["stats"]["modelled_time_s"] > 0
    # equivalent one-shot shuffle of the concatenated feeds (integer payloads:
    # sums are exact under any fold order)
    merged = {w: Msgs.concat([f[w] for f in feeds]) for w in WORKERS}
    ref = TeShuService(topo).shuffle("vanilla_push", merged, WORKERS, WORKERS,
                                     comb_fn=SUM)
    for d in WORKERS:
        np.testing.assert_array_equal(ref.bufs[d].keys, out["bufs"][d].keys)
        np.testing.assert_array_equal(ref.bufs[d].vals, out["bufs"][d].vals)
    with pytest.raises(RuntimeError):
        sess.feed(feeds[0])
    with pytest.raises(RuntimeError):
        sess.drain()


def test_feed_backpressure_enforced():
    """max_inflight is enforced, not merely modelled: the window of
    transferred-but-unfolded chunks never exceeds the bound (feed() spills
    the oldest chunks into the fold when it fills), and the window size never
    changes the drained bytes."""
    svc = TeShuService(_topo(), streaming="auto", chunk_bytes=240)
    rng = np.random.default_rng(4)
    feeds = [{w: Msgs(rng.integers(0, 32, 400), rng.random((400, 1)))
              for w in WORKERS[:4]} for _ in range(2)]

    tight = svc.open_stream("vanilla_push", WORKERS[:4], WORKERS,
                            comb_fn=SUM, max_inflight=2)
    for f in feeds:
        tight.feed(_copy(f))
        assert tight.inflight <= 2            # bound holds between feeds too
    assert tight.max_inflight_observed <= 2
    assert tight.backpressure_stalls > 0      # the producer really was held
    out_tight = tight.drain()
    assert tight.inflight == 0                # drain flushes the window

    wide = svc.open_stream("vanilla_push", WORKERS[:4], WORKERS,
                           comb_fn=SUM, max_inflight=10_000)
    for f in feeds:
        wide.feed(_copy(f))
    assert wide.backpressure_stalls == 0
    assert wide.max_inflight_observed > 2     # the window genuinely deferred
    out_wide = wide.drain()
    assert out_tight["chunks"] == out_wide["chunks"]
    for d in WORKERS:
        np.testing.assert_array_equal(out_tight["bufs"][d].keys,
                                      out_wide["bufs"][d].keys)
        np.testing.assert_array_equal(out_tight["bufs"][d].vals,
                                      out_wide["bufs"][d].vals)


def test_feed_drain_bounded_state_and_guards():
    svc = TeShuService(_topo(), streaming="auto", chunk_bytes=240)
    with pytest.raises(ValueError):
        svc.open_stream("bruck", WORKERS, WORKERS)
    sess = svc.open_stream("vanilla_push", WORKERS[:4], WORKERS, comb_fn=SUM)
    with pytest.raises(ValueError):
        sess.feed({7: Msgs(np.arange(3), np.ones((3, 1)))})   # not a source
    rng = np.random.default_rng(0)
    sizes = []
    for _ in range(4):                    # accumulator stays O(distinct keys)
        sess.feed({w: Msgs(rng.integers(0, 16, 500), rng.random((500, 1)))
                   for w in WORKERS[:4]})
        sizes.append(max(m.n for m in sess.acc.values() if m is not None))
    assert max(sizes) <= 16
    out = sess.drain()
    assert sum(m.n for m in out["bufs"].values()) <= 16


# ---------------------------------------------------------------------------
# Satellite: adaptive sketch capacity
# ---------------------------------------------------------------------------

def test_adaptive_sketch_capacity_bounds():
    # detection floor: hot keys stay detectable at any fan-out
    assert adaptive_sketch_capacity(100, 256) >= 256 / HOT_KEY_FRACTION
    # sqrt-of-universe scaling, clamped
    assert adaptive_sketch_capacity(2**16 - 1, 8) == 256
    assert adaptive_sketch_capacity(2**40, 8) == MAX_SKETCH_CAPACITY
    assert adaptive_sketch_capacity(100, 2) == MIN_SKETCH_CAPACITY
    assert adaptive_sketch_capacity(0, 2) == MIN_SKETCH_CAPACITY


def test_local_skew_stats_adaptive_capacity_and_exactness():
    rng = np.random.default_rng(4)
    small = Msgs(rng.integers(0, 50, 5000), np.ones((5000, 1)))
    st_small = local_skew_stats(small, HASH_PART, 8)
    assert st_small.sketch.capacity == MIN_SKETCH_CAPACITY
    assert st_small.sketch.error_bound == 0       # universe fits: exact
    big = Msgs(rng.integers(0, 2**32, 5000), np.ones((5000, 1)))
    st_big = local_skew_stats(big, HASH_PART, 8)
    assert st_big.sketch.capacity == MAX_SKETCH_CAPACITY


def test_adaptive_capacity_merge_preserves_error_bound():
    rng = np.random.default_rng(6)
    a_keys = rng.integers(0, 300, 20000)
    b_keys = rng.integers(0, 2**20, 20000)
    a = HeavyHitterSketch.from_keys(a_keys, adaptive_sketch_capacity(299, 8))
    b = HeavyHitterSketch.from_keys(b_keys, adaptive_sketch_capacity(2**20, 8))
    merged = a.merge(b)
    assert merged.capacity == max(a.capacity, b.capacity)
    assert merged.error_bound <= a.error_bound + b.error_bound
    pooled = np.concatenate([a_keys, b_keys])
    uniq, cnt = np.unique(pooled, return_counts=True)
    true = dict(zip(uniq.tolist(), cnt.tolist()))
    for k, c in merged.counts.items():            # undercount within the bound
        assert 0 < c <= true[k]
        assert true[k] - c <= merged.error_bound


# ---------------------------------------------------------------------------
# Satellite: skew-aware EFF/COST coupling
# ---------------------------------------------------------------------------

def test_recv_imbalance_scales_eff_term():
    topo = _topo()
    base = eff_cost_from_ratio(topo, "server", 0.5, 1e6, 2)
    hot = eff_cost_from_ratio(topo, "server", 0.5, 1e6, 2, recv_imbalance=3.0)
    assert hot.eff == pytest.approx(3.0 * base.eff)
    assert hot.cost == base.cost                  # only the tail savings scale
    assert hot.recv_imbalance == 3.0 and base.recv_imbalance == 1.0
    # clamped: observed imbalance below 1 never penalizes
    assert eff_cost_from_ratio(topo, "server", 0.5, 1e6, 2,
                               recv_imbalance=0.25).eff == base.eff


def test_hot_destination_flips_borderline_combine_decision():
    """A stage whose EFF/COST verdict is borderline-negative on balanced
    history becomes beneficial once the ledger shows a hot destination: the
    bytes a combine removes shorten the tail the epoch is gated on."""
    topo = _topo()
    r_hat, group_bytes, g = 0.95, 1e6, 4
    cold = eff_cost_from_ratio(topo, "rack", r_hat, group_bytes, g)
    hot = eff_cost_from_ratio(topo, "rack", r_hat, group_bytes, g,
                              recv_imbalance=4.0)
    assert not cold.beneficial and hot.beneficial


def test_repair_carries_frozen_recv_imbalance():
    """A verdict that was beneficial only because of the hot-destination
    factor must stay so through plan repair: the repaired EffCost is exactly
    what instantiation computed on the degraded topology, imbalance included."""
    from repro.core import (compile_plan, plan_key, repair_plan, degrade_links)
    topo = _topo()
    bufs = _bufs(n=100)
    ec = eff_cost_from_ratio(topo, "rack", 0.95, 1e6, 4, recv_imbalance=4.0)
    assert ec.beneficial
    key = plan_key("network_aware", topo, tuple(WORKERS), tuple(WORKERS),
                   stats_signature(bufs, HASH_PART, SUM, 0.05))
    plan = compile_plan(key, "network_aware", topo, WORKERS, WORKERS,
                        [("rack", ec)])
    deg = degrade_links(topo, "server", 0.5)   # global-EFF untouched boundary
    deg = degrade_links(deg, "global", 0.5)    # ...and one that repairs rack
    key2 = plan_key("network_aware", deg, tuple(WORKERS), tuple(WORKERS),
                    stats_signature(bufs, HASH_PART, SUM, 0.05))
    repaired, levels = repair_plan(plan, key2, deg)
    assert "rack" in levels
    got = repaired.level("rack").eff_cost
    assert got.recv_imbalance == 4.0
    assert got == eff_cost_from_ratio(deg, "rack", 0.95, 1e6, 4,
                                      recv_imbalance=4.0)
