"""Elastic topology: burst workers, mid-batch scale-out, graceful drain-in.

Pins the PR-10 acceptance criteria:

* a mid-batch scale-out (manual policy, ``after_coflows=1``) produces
  byte-identical outputs to the same trace on a fixed cluster born at the
  grown size, on both replay executors, with unchanged per-tenant ledger
  byte lanes;
* graceful scale-in loses zero staged store blocks (journal-asserted
  ``drain_handoff``), charges burst worker-seconds to the sponsoring
  tenants, and clears the victims' fault state;
* scaling is O(1) for the plan cache: the epoch in the topology tag makes
  stale plans unreachable without a namespace scan, and plan repair re-keys
  them back (``epoch_rekey``) when the topology returns to a known shape;
* a cold miss on a healthy, never-scaled cluster never triggers a repair
  scan (the regression the ``has_repair_relatives`` gate exists for);
* the failure detector and speculation work unchanged on a grown topology —
  burst workers are first-class: they can straggle, die, and host backups;
* journal schema v3 (``scale_out`` / ``scale_in`` / ``drain_handoff``)
  round-trips, pre-elastic v2 journals still replay, and the doctor renders
  the cluster elastic timeline.
"""
import json
import os

import pytest

from conformance import (assert_identical, assert_msgs_identical, copy_bufs,
                         make_bufs, make_topology)
from repro.core import (DEFAULT_TENANT, ShuffleManager, TeShuCluster,
                        TeShuService, datacenter, key_diff, plan_key,
                        stats_signature)
from repro.core.elastic import (HOLD, BacklogPolicy, LoadMonitor, ManualPolicy,
                                SCALE_DENIED_COOLDOWN, SCALE_IN_IDLE,
                                SCALE_IN_TTL, SCALE_OUT_BACKLOG,
                                SCALE_REASON_MANUAL, ScaleDecision)
from repro.core.manager import JOURNAL_VERSION
from repro.core.plancache import split_topology_tag, topology_tag
from repro.launch import doctor

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
W8 = tuple(range(8))
W12 = tuple(range(12))


def _bufs(workers, n=300, keys=64, seed=0):
    return make_bufs(workers, "uniform", n=n, key_space=keys, seed=seed)


def _grown_topo():
    """The 8-worker conformance fabric grown by one rack = born-12 fabric."""
    return datacenter(2, 2, 3, oversubscription=4.0)


# ---------------------------------------------------------------------------
# topology resizing
# ---------------------------------------------------------------------------

def test_topology_grow_shrink_roundtrip():
    base = make_topology()
    assert base.num_workers == 8
    grown = base.grow(1, "rack")
    assert grown.num_workers == 12
    # a grown fabric is indistinguishable from one born at that size
    assert grown.fingerprint() == _grown_topo().fingerprint()
    # inner-level membership of existing workers is untouched
    for w in W8:
        assert grown.coords(w)[:2] == base.coords(w)[:2]
    assert grown.shrink(4).fingerprint() == base.fingerprint()
    assert base.with_workers(10).num_workers == 10
    with pytest.raises(ValueError):
        base.grow(0)
    with pytest.raises(ValueError):
        base.grow(1, "global")          # the outermost group IS the cluster
    with pytest.raises(ValueError):
        base.shrink(8)                  # can't remove the whole cluster
    with pytest.raises(ValueError):
        base.with_workers(0)


def test_epoch_tagged_plan_keys():
    topo = make_topology()
    fp = topo.fingerprint()
    assert topology_tag(topo, 0) == fp              # epoch 0 = legacy bare tag
    tagged = topology_tag(topo, 2)
    assert split_topology_tag(tagged) == (fp, 2)
    assert split_topology_tag(fp) == (fp, 0)
    k0 = plan_key("vanilla_pull", topo, W8, W8, ("sig",), epoch=0)
    k2 = plan_key("vanilla_pull", topo, W8, W8, ("sig",), epoch=2)
    assert k0 != k2
    assert key_diff(k0, k2) == ["topology.epoch"]


# ---------------------------------------------------------------------------
# policies / signals (unit)
# ---------------------------------------------------------------------------

def test_load_monitor_signals():
    mon = LoadMonitor(window=4)
    with pytest.raises(ValueError):
        LoadMonitor(window=1)
    assert mon.latest() is None and mon.backlog_seconds() == 0.0
    mon.record(ts=0.0, queue_depth=3, pending_coflows=3,
               tenant_bytes={"ml": 0})
    assert mon.backlog_seconds() == 0.0             # no realized CCT yet
    mon.record(ts=2.0, queue_depth=0, pending_coflows=4,
               tenant_bytes={"ml": 1000}, ccts=(0.5, 1.5))
    assert mon.mean_cct() == 1.0
    assert mon.backlog_seconds() == 4.0             # 4 pending x mean CCT 1.0
    assert mon.byte_rates() == {"ml": 500.0}
    for i in range(10):                             # bounded window
        mon.record(ts=3.0 + i, queue_depth=0, pending_coflows=0)
    assert len(mon.samples()) == 4


def test_backlog_policy_grow_deny_hysteresis():
    pol = BacklogPolicy(backlog_coflows=3, cooldown_s=10.0, hysteresis=2)
    mon = LoadMonitor()
    kw = dict(executed_coflows=0, at_capacity=False, has_burst=False)
    assert pol.evaluate(mon, pending_coflows=2, now=0.0, **kw) is HOLD
    d = pol.evaluate(mon, pending_coflows=3, now=0.0, **kw)
    assert d.action == "grow" and d.reason == SCALE_OUT_BACKLOG
    pol.note_scaled(0.0)
    # cooldown: the backlog is still there but scaling is suppressed loudly
    d = pol.evaluate(mon, pending_coflows=5, now=1.0, **kw)
    assert d.action == "deny" and d.reason == SCALE_DENIED_COOLDOWN
    # at capacity we hold quietly (there is nothing to deny)
    assert pol.evaluate(mon, pending_coflows=5, now=100.0,
                        executed_coflows=0, at_capacity=True,
                        has_burst=True) is HOLD
    # hysteresis: one idle poll never drains; two consecutive ones do
    assert pol.idle(mon, has_burst=True, now=100.0) is HOLD
    d = pol.idle(mon, has_burst=True, now=101.0)
    assert d.action == "shrink" and d.reason == SCALE_IN_IDLE
    # a boundary evaluation resets the idle streak
    pol.evaluate(mon, pending_coflows=0, now=102.0, **kw)
    assert pol.idle(mon, has_burst=True, now=103.0) is HOLD
    # no burst workers -> nothing to shrink, streak stays flat
    assert pol.idle(mon, has_burst=False, now=104.0) is HOLD


def test_manual_policy_queue():
    pol = ManualPolicy()
    with pytest.raises(ValueError):
        pol.request(ScaleDecision(action="hold"))
    pol.request(ScaleDecision(action="grow", reason=SCALE_REASON_MANUAL,
                              groups=1), after_coflows=1)
    mon = LoadMonitor()
    kw = dict(pending_coflows=3, at_capacity=False, has_burst=False, now=0.0)
    assert pol.evaluate(mon, executed_coflows=0, **kw) is HOLD
    d = pol.evaluate(mon, executed_coflows=1, **kw)
    assert d.action == "grow"
    assert pol.evaluate(mon, executed_coflows=2, **kw) is HOLD  # one-shot
    # idle pops an armed decision regardless of its threshold
    pol.request(ScaleDecision(action="shrink", reason=SCALE_REASON_MANUAL),
                after_coflows=99)
    assert pol.idle(mon, has_burst=True, now=0.0).action == "shrink"


# ---------------------------------------------------------------------------
# the tentpole: mid-batch scale-out, byte-identical to a fixed cluster
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["vectorized", "jax"])
def test_mid_batch_scale_out_byte_identical(executor):
    base = [_bufs(W8, seed=10 + i) for i in range(3)]

    el = TeShuCluster(make_topology(), execution="auto", executor=executor,
                      elastic="manual", elastic_level="rack")
    t_el = el.tenant("ml")
    el_tickets = [t_el.submit("vanilla_pull", copy_bufs(base[i]), W8, W8,
                              stage=f"s{i}") for i in range(3)]
    el.request_scale_out(after_coflows=1)     # fires between coflow 0 and 1
    el_res = el.run_pending(policy="fifo")

    # the fixed reference: born at 12 workers, same trace with the widening
    # the elastic run performed (coflow 0 narrow, 1 and 2 on everyone)
    fx = TeShuCluster(_grown_topo(), execution="auto", executor=executor)
    t_fx = fx.tenant("ml")
    fx_tickets = [t_fx.submit("vanilla_pull", copy_bufs(base[i]), W8,
                              W8 if i == 0 else W12, stage=f"s{i}")
                  for i in range(3)]
    fx_res = fx.run_pending(policy="fifo")

    for i in range(3):
        r_el, r_fx = el_res[el_tickets[i]], fx_res[fx_tickets[i]]
        assert not isinstance(r_el, Exception)
        assert sorted(r_el.bufs) == sorted(r_fx.bufs)
        assert_identical(r_el.bufs, r_fx.bufs)
    # coflows after the boundary really landed on the burst workers
    assert sorted(el_res[el_tickets[0]].bufs) == list(W8)
    assert sorted(el_res[el_tickets[1]].bufs) == list(W12)
    # per-tenant ledger byte lanes are unchanged by elasticity
    assert (el.cluster.ledger.tenant_bytes()
            == fx.cluster.ledger.tenant_bytes())
    # the realized schedule carries the scale event
    events = el.last_schedule()["scale_events"]
    assert [e["kind"] for e in events] == ["scale_out"]
    assert events[0]["workers"] == [8, 9, 10, 11]
    assert events[0]["size"] == 12 and events[0]["epoch"] == 1
    assert el.elastic_epoch == 1
    assert el.scale_events() == events

    # warm pass B: the same narrow trace re-targets onto the full grown set
    # and the widened coflows replay their pass-A plans on the requested
    # engine -- cache keys (epoch included) survived the scale event
    el_tickets_b = [t_el.submit("vanilla_pull", copy_bufs(base[i]), W8, W8,
                                stage=f"s{i}") for i in range(3)]
    el_res_b = el.run_pending(policy="fifo")
    fx_tickets_b = [t_fx.submit("vanilla_pull", copy_bufs(base[i]), W8, W12,
                                stage=f"s{i}") for i in range(3)]
    fx_res_b = fx.run_pending(policy="fifo")
    for i in range(3):
        r_el, r_fx = el_res_b[el_tickets_b[i]], fx_res_b[fx_tickets_b[i]]
        assert not isinstance(r_el, Exception)
        assert_identical(r_el.bufs, r_fx.bufs)
        assert sorted(r_el.bufs) == list(W12)
    for i in (1, 2):                          # pass-A plans, requested engine
        r = el_res_b[el_tickets_b[i]]
        assert r.cached and r.engine == executor
    assert el.last_schedule()["scale_events"] == []   # pass B never scaled


def test_scale_requests_demand_manual_mode():
    cl = TeShuCluster(make_topology())
    with pytest.raises(RuntimeError):
        cl.scale_out()
    with pytest.raises(RuntimeError):
        cl.request_scale_out()
    assert cl.scale_events() == [] and cl.elastic_epoch == 0
    auto = TeShuCluster(make_topology(), elastic="auto")
    with pytest.raises(RuntimeError):
        auto.request_scale_out()        # armed requests are manual-mode only
    assert auto.scale_out() != ()       # the immediate ops hook always works


# ---------------------------------------------------------------------------
# O(1) invalidation + repair re-keying across epochs
# ---------------------------------------------------------------------------

def test_epoch_rekey_repairs_returning_topology():
    cl = TeShuCluster(make_topology(), execution="auto",
                      elastic="manual", elastic_level="rack")
    t = cl.tenant("ml")
    bufs = _bufs(W8)
    first = t.shuffle("vanilla_pull", copy_bufs(bufs), W8, W8)
    assert not first.cached
    added = cl.scale_out(tenants=("ml",))
    assert added == (8, 9, 10, 11)
    assert cl.scale_in() == (8, 9, 10, 11)
    # same fingerprint as at epoch 0, but the key's epoch makes the cached
    # plan unreachable -- repair re-keys it instead of recompiling
    assert cl.elastic_epoch == 2
    again = t.shuffle("vanilla_pull", copy_bufs(bufs), W8, W8)
    assert again.repaired and again.cached
    assert_identical(first.bufs, again.bufs)
    # and the re-keyed plan is a plain hit from now on
    third = t.shuffle("vanilla_pull", copy_bufs(bufs), W8, W8)
    assert third.cached and not third.repaired


def test_cold_healthy_miss_never_scans_for_repair():
    cl = TeShuCluster(make_topology(), execution="auto",
                      resilience="recover")
    t = cl.tenant("ml")
    t.shuffle("vanilla_pull", _bufs(W8, seed=1), W8, W8)
    t.shuffle("vanilla_pull", _bufs(W8, n=900, keys=16, seed=2), W8, W8)
    # two cold misses on a healthy, never-scaled cluster: no candidate can
    # exist by construction, so the repair path must not scan the namespace
    assert cl.plan_cache.scans == 0
    # sanity: a genuine repair scenario (survivor-subset resubmit) does scan
    survivors = tuple(w for w in W8 if w != 3)
    res = t.shuffle("vanilla_pull", _bufs(survivors, seed=1), survivors, W8)
    assert cl.plan_cache.scans > 0
    assert res.repaired


# ---------------------------------------------------------------------------
# graceful drain-in: zero lost blocks, burst accounting, clean fault state
# ---------------------------------------------------------------------------

def test_scale_in_drains_staged_blocks_and_charges_sponsors(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    cl = TeShuCluster(make_topology(), execution="auto",
                      elastic="manual", elastic_level="rack",
                      journal_path=path)
    t = cl.tenant("ml")
    cl.scale_out(tenants=("ml",))
    bufs = _bufs(W12, seed=5)
    t.shuffle("vanilla_pull", copy_bufs(bufs), W12, W12)   # modelled time > 0
    # stage blocks whose *source* is a burst worker: the scale-in handoff
    # must flush them to the backend before the worker leaves
    parts9 = {0: bufs[9], 3: bufs[10]}
    assert cl.store.put_parts("ml", 77, "late", 9, parts9)
    assert cl.store.put_parts("ml", 77, "late", 1, {0: bufs[1]})
    cl.delay_worker(10, 5.0)
    cl.fail_worker(11)

    removed = cl.scale_in()
    assert removed == (8, 9, 10, 11)
    assert cl.topology.num_workers == 8 and cl.elastic_epoch == 2
    # zero staged blocks lost: the drained worker's data is still served
    for d, m in parts9.items():
        got = cl.store.get_block("ml", 77, "late", 9, d)
        assert got is not None
        assert_msgs_identical(got, m)
    # journal-asserted handoff (cluster-scope pseudo shuffle id -1)
    handoffs = cl.manager.records(-1, kind="drain_handoff")
    assert len(handoffs) == 1
    info = handoffs[0].info
    assert info["workers"] == [8, 9, 10, 11]
    assert info["blocks"] == 2 and info["bytes"] > 0
    assert cl.manager.records(-1, kind="scale_in")
    # burst worker-seconds are charged to the sponsoring tenant
    assert cl.registry.burst_usage("ml") > 0.0
    assert t.stats()["burst_worker_s"] == cl.registry.burst_usage("ml")
    # removed ids leave no ghost fault state behind
    assert 10 not in cl.cluster.worker_delays
    assert 11 not in cl.cluster.failed_workers
    # the journal replays the scale records (schema v3 round-trip)
    cl.manager.close()
    mgr = ShuffleManager.recover(path)
    assert mgr.records(-1, kind="scale_out")
    assert mgr.records(-1, kind="scale_in")
    assert mgr.records(-1, kind="drain_handoff")
    mgr.close()


def test_scale_in_never_removes_base_workers():
    cl = TeShuCluster(make_topology(), elastic="manual")
    assert cl.scale_in() == ()                     # nothing bursting
    cl.scale_out()
    assert cl.scale_in(workers=(3, 4)) == ()       # base workers refused
    assert cl.topology.num_workers > 8
    assert cl.scale_in() != ()
    assert cl.topology.num_workers == 8


# ---------------------------------------------------------------------------
# auto policy end-to-end: backlog grow, idle drain, cooldown deny, TTL
# ---------------------------------------------------------------------------

def test_auto_policy_grows_on_backlog_and_drains_idle():
    cl = TeShuCluster(make_topology(), execution="auto", elastic="auto",
                      elastic_level="server", elastic_backlog=2,
                      elastic_hysteresis=1)
    t = cl.tenant("ml")
    tickets = [t.submit("vanilla_pull", _bufs(W8, seed=i), W8, W8,
                        stage=f"s{i}") for i in range(3)]
    res = cl.run_pending(policy="fifo")
    assert all(not isinstance(res[tk], Exception) for tk in tickets)
    events = cl.last_schedule()["scale_events"]
    kinds = [e["kind"] for e in events]
    assert kinds[0] == "scale_out"
    assert events[0]["reason"] == SCALE_OUT_BACKLOG
    assert kinds[-1] == "scale_in"
    assert events[-1]["reason"] == SCALE_IN_IDLE
    # every burst worker drained at the pass-end idle point
    assert cl.topology.num_workers == 8
    assert cl._elastic.burst == {}
    # coflows admitted after the grow really ran wide
    assert len(res[tickets[2]].bufs) > 8


def test_auto_policy_denies_during_cooldown():
    cl = TeShuCluster(make_topology(), execution="auto", elastic="auto",
                      elastic_level="server", elastic_backlog=2,
                      elastic_cooldown_s=1e9)
    t = cl.tenant("ml")
    for i in range(3):
        t.submit("vanilla_pull", _bufs(W8, seed=i), W8, W8, stage=f"s{i}")
    cl.run_pending(policy="fifo")
    events = cl.last_schedule()["scale_events"]
    kinds = [e["kind"] for e in events]
    assert kinds[0] == "scale_out"                 # first grow is free
    assert "deny" in kinds                         # later backlog suppressed
    deny = next(e for e in events if e["kind"] == "deny")
    assert deny["reason"] == SCALE_DENIED_COOLDOWN
    assert kinds.count("scale_out") == 1


def test_ttl_expiry_drains_at_idle_poll():
    cl = TeShuCluster(make_topology(), elastic="manual",
                      elastic_level="server", elastic_ttl_s=0.0)
    cl.scale_out()
    assert cl.topology.num_workers == 10
    assert cl.run_pending() == {}                  # quiescent poll
    assert cl.topology.num_workers == 8
    assert [e["reason"] for e in cl.scale_events()
            if e["kind"] == "scale_in"] == [SCALE_IN_TTL]


def test_max_workers_caps_growth():
    cl = TeShuCluster(make_topology(), elastic="manual",
                      elastic_level="rack", elastic_max_workers=12)
    assert cl.scale_out() == (8, 9, 10, 11)
    assert cl.scale_out() == ()                    # at capacity: deny, no-op
    assert cl.topology.num_workers == 12
    assert cl.scale_events()[-1]["kind"] == "deny"


# ---------------------------------------------------------------------------
# detector / speculation on a grown topology (satellite 4)
# ---------------------------------------------------------------------------

def test_burst_worker_straggler_speculated():
    cl = TeShuCluster(make_topology(), execution="threaded",
                      resilience="recover", elastic="manual",
                      elastic_level="rack")
    t = cl.tenant("ml")
    cl.scale_out(tenants=("ml",))
    bufs = _bufs(W12, seed=9, n=800)
    clean = t.shuffle("vanilla_pull", copy_bufs(bufs), W12, W12)
    cl.delay_worker(10, 0.6)                       # a burst worker straggles
    spec = t.shuffle("vanilla_pull", copy_bufs(bufs), W12, W12)
    assert spec.attempts == 1
    assert spec.recovery["speculated"] == [10]
    assert cl.manager.records(kind="speculation")
    assert_identical(clean.bufs, spec.bufs)


def test_burst_worker_death_recovers():
    cl = TeShuCluster(make_topology(), execution="threaded",
                      resilience="recover", elastic="manual",
                      elastic_level="rack")
    t = cl.tenant("ml")
    cl.scale_out(tenants=("ml",))
    bufs = _bufs(W12, seed=11, n=800)
    clean = t.shuffle("vanilla_pull", copy_bufs(bufs), W12, W12)
    cl.fail_worker(9)                              # dead, not slow
    rec = t.shuffle("vanilla_pull", copy_bufs(bufs), W12, W12)
    assert rec.attempts == 2
    assert rec.recovery["restarted"] == [9]
    assert not cl.cluster.failed_workers
    assert_identical(clean.bufs, rec.bufs)


# ---------------------------------------------------------------------------
# journal schema v3 + doctor timeline (satellites 1 & 2)
# ---------------------------------------------------------------------------

def test_journal_v3_and_pre_elastic_migration():
    assert JOURNAL_VERSION == 3
    fixture = os.path.join(FIXTURES, "pre_elastic_journal.jsonl")
    mgr = ShuffleManager.recover(fixture)
    recs = mgr.records()
    assert len(recs) == 9
    assert {r.version for r in recs} == {2}        # v2 lines replay verbatim
    assert mgr.records(2, kind="restore")
    assert mgr.progress(1)["pending"] == []
    assert not mgr.records(kind="scale_out")       # and carry no v3 kinds
    mgr.close()


def test_doctor_renders_cluster_elastic_timeline(tmp_path, capsys):
    path = str(tmp_path / "journal.jsonl")
    cl = TeShuCluster(make_topology(), execution="auto", elastic="manual",
                      elastic_level="rack", journal_path=path)
    t = cl.tenant("ml")
    cl.scale_out(tenants=("ml",))
    t.shuffle("vanilla_pull", _bufs(W12, seed=3), W12, W12)
    assert cl.store.put_parts("ml", 55, "late", 9, {0: _bufs(W8)[0]})
    cl.scale_in()
    cl.manager.close()

    reports = doctor.diagnose(path)
    cluster = [r for r in reports if r.get("kind") == "cluster"]
    assert len(cluster) == 1
    c = cluster[0]
    assert c["shuffle_id"] is None
    assert [e["kind"] for e in c["scale_events"]] == ["scale_out", "scale_in"]
    assert len(c["drain_handoffs"]) == 1
    assert c["drain_handoffs"][0]["blocks"] == 1
    # every burst worker's lifetime is closed out by the scale-in
    lifetimes = c["burst_worker_lifetimes"]
    assert sorted(lifetimes) == ["10", "11", "8", "9"]
    assert all(s is not None and s >= 0 for s in lifetimes.values())
    # per-shuffle verdicts never absorb the cluster-scope pseudo id -1
    assert all(r["shuffle_id"] >= 0 for r in reports
               if r.get("kind") != "cluster")
    # restricting to one shuffle drops the cluster entry
    sid = next(r["shuffle_id"] for r in reports if r.get("kind") != "cluster")
    only = doctor.diagnose(path, shuffle_id=sid)
    assert all(r.get("kind") != "cluster" for r in only)

    text = doctor.render(reports)
    assert "cluster elastic timeline:" in text
    assert "scale_out [manual]" in text
    assert "drain handoff" in text
    assert "burst worker 8" in text

    assert doctor.main([path]) == 0
    capsys.readouterr()
    assert doctor.main([path, "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert any(r.get("kind") == "cluster" for r in payload)


def test_explain_reports_elastic_epoch():
    cl = TeShuCluster(make_topology(), execution="auto", elastic="manual",
                      elastic_level="rack")
    t = cl.tenant("ml")
    cl.scale_out(tenants=("ml",))
    t.shuffle("vanilla_pull", _bufs(W12, seed=7), W12, W12)
    sid = max(r.shuffle_id for r in cl.manager.records() if r.shuffle_id >= 0)
    rep = cl.explain(sid)
    assert rep.elastic == {"epoch": 1, "workers": 12,
                           "burst": [8, 9, 10, 11]}
    assert any("elastically scaled topology" in line for line in rep.why())


def test_scale_metrics_and_gauges():
    cl = TeShuCluster(make_topology(), elastic="manual", elastic_level="rack")
    cl.scale_out(tenants=("ml",))
    m = cl.obs.metrics
    assert m.get("teshu_scale_events_total",
                 kind="scale_out", reason="manual") == 1.0
    assert m.get("teshu_cluster_workers") == 12.0
    assert m.get("teshu_burst_workers") == 4.0
    cl.scale_in()
    assert m.get("teshu_scale_events_total",
                 kind="scale_in", reason="manual") == 1.0
    assert m.get("teshu_cluster_workers") == 8.0
    assert m.get("teshu_burst_workers") == 0.0
    assert m.get("teshu_burst_worker_seconds", tenant="ml") >= 0.0
