"""Shared executor-conformance harness (not a test module).

One definition of the cross-executor byte-identity contract, used by
``test_conformance.py`` (the full {threaded, vectorized, jax} x template x
workload x {fresh, cache-hit} matrix), ``test_jaxplan.py`` (the jitted
executor's own suite), and the streaming / skew / multitenant suites (which
previously each carried their own copies of the topology, workload, copy,
and byte-compare helpers).

The contract these helpers express:

* **Outputs** are compared *bit-identically* — ``assert_identical`` for
  per-destination buffers in physical order, ``assert_sorted_identical``
  when only the combined multiset is pinned (e.g. across a rebalance).
* **Ledger stats** are compared exactly for every byte-denominated key and
  for modelled time (all three executors charge the same transfers in the
  same epochs); only the per-tenant *cost* lane is compared to within
  float tolerance — it is a running float sum whose baseline includes the
  fresh instantiation run, where thread scheduling permutes charge order
  at the last ulp.
"""
import math

import numpy as np

from repro.core import Msgs, TeShuService, datacenter

ALL_TEMPLATES = ("vanilla_push", "vanilla_pull", "coordinated", "bruck",
                 "two_level", "network_aware")
EXECUTORS = ("threaded", "vectorized", "jax")
WORKLOADS = ("uniform", "zipf")
WORKERS = list(range(8))

# Templates the batched-numpy replay supports.  The jitted replay now covers
# every built-in template, including the irregular bruck / two_level routes
# (asserted against repro.core identities in test_conformance).
VECTORIZED_TEMPLATES = frozenset(
    {"vanilla_push", "vanilla_pull", "coordinated", "network_aware"})
JAX_TEMPLATES = frozenset(ALL_TEMPLATES)


def make_topology(**kw):
    """The 8-worker, 3-level conformance fabric (2 racks x 2 servers x 2)."""
    kw.setdefault("oversubscription", 4.0)
    return datacenter(2, 2, 2, **kw)


def workers_for(template):
    """two_level asserts a square worker grid (q*q == nworkers): it runs the
    matrix on the 4-worker (q=2) subset; everything else on all 8."""
    return WORKERS[:4] if template == "two_level" else WORKERS


def zipf_keys(rng, n, key_space=64, alpha=1.2):
    """Zipf(alpha)-distributed keys over [0, key_space) via inverse-CDF."""
    ranks = np.arange(1, key_space + 1, dtype=np.float64)
    w = ranks ** -alpha
    cdf = np.cumsum(w) / np.sum(w)
    return np.searchsorted(cdf, rng.random(n)).astype(np.int64)


def make_bufs(workers, workload="uniform", n=300, key_space=64, width=2,
              seed=7):
    """Per-worker keyed buffers for one conformance workload."""
    rng = np.random.default_rng(seed)
    out = {}
    for w in workers:
        keys = (zipf_keys(rng, n, key_space) if workload == "zipf"
                else rng.integers(0, key_space, n).astype(np.int64))
        out[w] = Msgs(keys, rng.random((n, width)))
    return out


def copy_bufs(bufs):
    """Defensive copy: shuffles consume buffers; every run gets fresh ones."""
    return {w: m.copy() for w, m in bufs.items()}


def service_for(executor, topo=None, **kw):
    """A service pinned to one executor.  ``"threaded"`` = the reference
    thread-per-worker path (still caching, so hits replay threaded);
    ``"vectorized"``/``"jax"`` = ``auto`` execution with that replay plane."""
    topo = make_topology() if topo is None else topo
    if executor == "threaded":
        return TeShuService(topo, execution="threaded", **kw)
    return TeShuService(topo, execution="auto", executor=executor, **kw)


# ---------------------------------------------------------------------------
# comparisons
# ---------------------------------------------------------------------------

def assert_msgs_identical(a: Msgs, b: Msgs):
    np.testing.assert_array_equal(a.keys, b.keys)
    np.testing.assert_array_equal(a.vals, b.vals)     # bit-identical floats


def assert_msgs_sorted_identical(a: Msgs, b: Msgs):
    oa = np.argsort(a.keys, kind="stable")
    ob = np.argsort(b.keys, kind="stable")
    np.testing.assert_array_equal(a.keys[oa], b.keys[ob])
    np.testing.assert_array_equal(a.vals[oa], b.vals[ob])


def assert_identical(a: dict, b: dict):
    """Per-destination buffers bit-identical in physical row order."""
    assert set(a) == set(b)
    for w in a:
        assert_msgs_identical(a[w], b[w])


def assert_sorted_identical(a: dict, b: dict):
    """Bit-identical up to a stable per-destination key sort (for paths that
    pin content but not arrival order, e.g. across a skew rebalance)."""
    assert set(a) == set(b)
    for w in a:
        assert_msgs_sorted_identical(a[w], b[w])


_EXACT_STATS = ("total_bytes", "sample_bytes", "bytes_per_level",
                "recv_bytes_per_worker", "bytes_per_tenant")


def assert_stats_identical(a: dict, b: dict):
    """Ledger-delta equivalence across executors (see module docstring)."""
    for k in _EXACT_STATS:
        assert a[k] == b[k], (k, a[k], b[k])
    # modelled time and per-tenant cost are deltas of running float sums whose
    # baseline includes the threaded fresh run (ulp-order scheduling jitter)
    assert math.isclose(a["modelled_time_s"], b["modelled_time_s"],
                        rel_tol=1e-9, abs_tol=1e-18), \
        (a["modelled_time_s"], b["modelled_time_s"])
    ca, cb = a["cost_per_tenant"], b["cost_per_tenant"]
    assert set(ca) == set(cb)
    for t in ca:
        assert math.isclose(ca[t], cb[t], rel_tol=1e-9, abs_tol=1e-18), \
            (t, ca[t], cb[t])


# ---------------------------------------------------------------------------
# the matrix cell
# ---------------------------------------------------------------------------

def conformance_case(template, workload, executor, *, comb_fn=None, seed=7,
                     **shuffle_kw):
    """Run one matrix cell: a fresh instantiation plus a cache-hit replay on
    a service pinned to ``executor``.  Returns ``(fresh, hit)`` results; the
    caller compares them across executors."""
    workers = workers_for(template)
    bufs = make_bufs(workers, workload, seed=seed)
    service = service_for(executor)
    fresh = service.shuffle(template, copy_bufs(bufs), workers, workers,
                            comb_fn=comb_fn, **shuffle_kw)
    hit = service.shuffle(template, copy_bufs(bufs), workers, workers,
                          comb_fn=comb_fn, **shuffle_kw)
    return fresh, hit


def expected_engine(template, executor):
    """Which data plane a cache-hit replay must report for a matrix cell:
    executors fall back down the jax -> vectorized -> threaded ladder for
    templates their lowering does not cover."""
    if executor == "jax" and template in JAX_TEMPLATES:
        return "jax"
    if executor in ("jax", "vectorized") \
            and template in VECTORIZED_TEMPLATES:
        return "vectorized"
    return "threaded"
