"""Plan-compilation cache: keying, hit/miss, drift invalidation, equivalence.

The contract under test (ISSUE 1 acceptance): a repeated shuffle with an
unchanged (template, topology, stats-signature) key hits the cache, skips
sampling/instantiation entirely, and produces *identical* outputs to a fresh
run — on both the threaded reference executor and the batched (vectorized)
data plane.
"""
import numpy as np
import pytest

from repro.core import (SUM, CompiledPlan, Msgs, PlanCache, TeShuService,
                        compile_plan, datacenter, fat_tree, multipod_dcn,
                        plan_key, reduction_drift, stats_signature)
from repro.core.messages import HASH_PART


def _dup_heavy(nw, n=400, blocks=40, key_space=4096, seed=3):
    """Heavy *cross-worker* key duplication: all workers draw from one shared
    key pool, so local combining at every level removes most bytes (the sample
    is taken after the per-worker combine, so only cross-worker duplication
    drives the EFF/COST estimate)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, key_space, blocks)
    base[0] = key_space - 1              # pin the key-space bucket
    out = {}
    for w in range(nw):
        keys = np.repeat(rng.permutation(base), n // blocks)
        out[w] = Msgs(keys, rng.random((keys.size, 1)))
    return out


def _unique_ish(nw, n=400, key_space=4096, seed=4):
    """Globally (near-)unique keys in the same space/shape as ``_dup_heavy``:
    disjoint per-worker ranges, so the combiner removes ~nothing even pooled."""
    rng = np.random.default_rng(seed)
    per = key_space // nw
    out = {}
    for w in range(nw):
        keys = w * per + rng.choice(per, size=n, replace=False)
        keys[0] = key_space - 1          # pin the key-space bucket (shared key)
        out[w] = Msgs(keys, rng.random((n, 1)))
    return out


def _copy(bufs):
    return {w: Msgs(m.keys.copy(), m.vals.copy()) for w, m in bufs.items()}


def _sorted_eq(a: Msgs, b: Msgs):
    oa, ob = np.argsort(a.keys), np.argsort(b.keys)
    np.testing.assert_array_equal(a.keys[oa], b.keys[ob])
    np.testing.assert_array_equal(a.vals[oa], b.vals[ob])   # bit-identical


# ---------------------------------------------------------------------------
# keying
# ---------------------------------------------------------------------------

def test_signature_stable_and_discriminating():
    bufs = _dup_heavy(4)
    s1 = stats_signature(bufs, HASH_PART, SUM, 0.05)
    s2 = stats_signature(_copy(bufs), HASH_PART, SUM, 0.05)
    assert s1 == s2                                  # identical workload -> hit
    assert s1 != stats_signature(bufs, HASH_PART, None, 0.05)   # combiner matters
    assert s1 != stats_signature(bufs, HASH_PART, SUM, 0.10)    # rate matters
    bigger = {w: Msgs(np.concatenate([m.keys] * 4),
                      np.concatenate([m.vals] * 4)) for w, m in bufs.items()}
    assert s1 != stats_signature(bigger, HASH_PART, SUM, 0.05)  # 4x data -> miss


def test_signature_tolerates_jitter_within_bucket():
    bufs = _dup_heavy(4, n=400)
    jittered = {w: Msgs(m.keys[:-3], m.vals[:-3]) for w, m in bufs.items()}
    assert stats_signature(bufs, HASH_PART, SUM, 0.05) == \
        stats_signature(jittered, HASH_PART, SUM, 0.05)


def test_plan_key_separates_topology_and_participants():
    bufs = _dup_heavy(8)
    sig = stats_signature(bufs, HASH_PART, SUM, 0.05)
    t1, t2 = datacenter(2, 2, 2), fat_tree(2, 2, 1, 2)
    w = tuple(range(8))
    assert plan_key("vanilla_push", t1, w, w, sig) != \
        plan_key("vanilla_push", t2, w, w, sig)
    assert plan_key("vanilla_push", t1, w, w, sig) != \
        plan_key("vanilla_push", t1, w, w[:4], sig)
    assert plan_key("vanilla_push", t1, w, w, sig) != \
        plan_key("bruck", t1, w, w, sig)


# ---------------------------------------------------------------------------
# cache mechanics
# ---------------------------------------------------------------------------

def _dummy_plan(key) -> CompiledPlan:
    return compile_plan(key, "vanilla_push", datacenter(2, 2, 2),
                        range(8), range(8), decisions=[])


def test_cache_hit_miss_lru_eviction():
    cache = PlanCache(capacity=2)
    k = [("t", i) for i in range(3)]
    assert cache.get(k[0]) is None                       # miss
    for key in k:
        cache.put(key, _dummy_plan(key))
    assert len(cache) == 2                               # capacity enforced
    assert cache.get(k[0]) is None                       # k[0] was LRU-evicted
    assert cache.get(k[2]) is not None
    st = cache.stats()
    assert st["evictions"] == 1 and st["hits"] == 1 and st["misses"] == 2


def test_cache_refresh_every_forces_reinstantiation():
    cache = PlanCache(refresh_every=2)
    key = ("t", 0)
    cache.put(key, _dummy_plan(key))
    assert cache.get(key) is not None
    assert cache.get(key) is not None
    assert cache.get(key) is None                        # 3rd hit -> forced refresh
    assert cache.stats()["refreshes"] == 1


def test_reduction_drift_thresholds():
    assert not reduction_drift(0.2, 0.3)                 # within tolerance
    assert reduction_drift(0.2, 0.4)
    assert reduction_drift(0.9, 0.2, tolerance=0.5)


# ---------------------------------------------------------------------------
# service integration: hit/miss + equivalence
# ---------------------------------------------------------------------------

TOPOLOGIES = {
    "datacenter": lambda: datacenter(2, 2, 2, oversubscription=4.0),
    "fat_tree": lambda: fat_tree(2, 2, 2, 1, edge_oversubscription=4.0),
    "multipod_dcn": lambda: multipod_dcn(2, 2, 2),
}


@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("template", ["vanilla_push", "vanilla_pull",
                                      "coordinated", "network_aware", "bruck"])
def test_cached_equals_fresh_all_executors(topo_name, template):
    topo = TOPOLOGIES[topo_name]()
    nw = topo.num_workers
    svc = TeShuService(topo)
    bufs = _dup_heavy(nw)
    workers = list(range(nw))

    fresh = svc.shuffle(template, _copy(bufs), workers, workers,
                        comb_fn=SUM, rate=0.05)
    assert not fresh.cached
    cached_vec = svc.shuffle(template, _copy(bufs), workers, workers,
                             comb_fn=SUM, rate=0.05)
    cached_thr = svc.shuffle(template, _copy(bufs), workers, workers,
                             comb_fn=SUM, rate=0.05, execution="threaded")
    assert cached_vec.cached and cached_thr.cached
    if template != "bruck":                # bruck falls back to threaded
        assert cached_vec.vectorized
    st = svc.cache_stats()
    assert st["misses"] == 1 and st["hits"] == 2

    assert set(fresh.bufs) == set(cached_vec.bufs) == set(cached_thr.bufs)
    for w in fresh.bufs:
        _sorted_eq(fresh.bufs[w], cached_vec.bufs[w])
        _sorted_eq(fresh.bufs[w], cached_thr.bufs[w])
    # byte accounting is identical across executors (same charges, same levels)
    assert cached_vec.stats["bytes_per_level"] == cached_thr.stats["bytes_per_level"]
    assert cached_vec.stats["total_bytes"] == cached_thr.stats["total_bytes"]


def test_cache_hit_skips_sampling_and_decisions_replayed():
    topo = datacenter(2, 2, 2, oversubscription=4.0)
    nw = topo.num_workers
    svc = TeShuService(topo)
    bufs = _dup_heavy(nw)
    workers = list(range(nw))
    fresh = svc.shuffle("network_aware", _copy(bufs), workers, workers,
                        comb_fn=SUM, rate=0.05)
    assert fresh.stats["sample_bytes"] > 0               # instantiation sampled
    hit = svc.shuffle("network_aware", _copy(bufs), workers, workers,
                      comb_fn=SUM, rate=0.05)
    assert hit.stats["sample_bytes"] == 0                # replay did not
    assert [lv for lv, _ in hit.decisions] == [lv for lv, _ in fresh.decisions]
    for (_, a), (_, b) in zip(fresh.decisions, hit.decisions):
        assert a.beneficial == b.beneficial


def test_execution_fresh_bypasses_cache():
    topo = datacenter(2, 2, 2)
    nw = topo.num_workers
    svc = TeShuService(topo, execution="fresh")
    bufs = _dup_heavy(nw)
    workers = list(range(nw))
    svc.shuffle("network_aware", _copy(bufs), workers, workers, comb_fn=SUM,
                rate=0.05)
    r = svc.shuffle("network_aware", _copy(bufs), workers, workers, comb_fn=SUM,
                    rate=0.05)
    assert not r.cached and r.stats["sample_bytes"] > 0
    assert svc.cache_stats()["hits"] == 0


# ---------------------------------------------------------------------------
# drift invalidation
# ---------------------------------------------------------------------------

def test_drift_invalidates_and_reinstantiates():
    """Same signature, different data distribution -> observed reduction drifts
    -> plan dropped -> next call re-instantiates from fresh samples."""
    topo = datacenter(2, 2, 2, oversubscription=10.0, combine_bytes_per_s=64e9)
    nw = topo.num_workers
    svc = TeShuService(topo)
    workers = list(range(nw))
    dup = _dup_heavy(nw, n=4000, blocks=100, key_space=65536)
    uniq = _unique_ish(nw, n=4000, key_space=65536)
    # both workloads must share the cache key or the test is vacuous
    assert stats_signature(dup, HASH_PART, SUM, 0.05) == \
        stats_signature(uniq, HASH_PART, SUM, 0.05)

    fresh = svc.shuffle("network_aware", _copy(dup), workers, workers,
                        comb_fn=SUM, rate=0.05)
    assert any(ec.beneficial for _, ec in fresh.decisions), \
        "duplication-heavy workload must trigger local combining"
    drifted = svc.shuffle("network_aware", _copy(uniq), workers, workers,
                          comb_fn=SUM, rate=0.05)
    assert drifted.cached                                # keyed the same -> hit
    assert svc.cache_stats()["invalidations"] == 1       # ...but drift detected
    again = svc.shuffle("network_aware", _copy(uniq), workers, workers,
                        comb_fn=SUM, rate=0.05)
    assert not again.cached                              # re-instantiated
    assert again.stats["sample_bytes"] > 0
    for _, ec in again.decisions:
        assert ec.reduction_ratio > 0.8                  # fresh samples see truth


def test_no_drift_keeps_plan():
    topo = datacenter(2, 2, 2, oversubscription=10.0)
    nw = topo.num_workers
    svc = TeShuService(topo)
    workers = list(range(nw))
    dup = _dup_heavy(nw, n=4000, blocks=100)
    svc.shuffle("network_aware", _copy(dup), workers, workers,
                comb_fn=SUM, rate=0.05)
    for seed in (5, 6, 7):                               # same distribution, new draws
        more = _dup_heavy(nw, n=4000, blocks=100, seed=seed)
        svc.shuffle("network_aware", _copy(more), workers, workers,
                    comb_fn=SUM, rate=0.05)
    st = svc.cache_stats()
    assert st["invalidations"] == 0 and st["hits"] == 3
