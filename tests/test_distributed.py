"""Distributed-path tests that need multiple XLA host devices.

jax fixes the device count at first init, so these run in subprocesses with
XLA_FLAGS set (same pattern as launch/dryrun.py).  Each subprocess prints
CHECK lines that the parent asserts on.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 900) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_moe_dispatch_templates_match_local():
    """teshu / teshu2 shard_map dispatch == local math (no-drop capacity)."""
    out = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.models.config import ModelConfig, MoEConfig
        from repro.models.moe import init_moe, moe_ffn
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        for disp in ("teshu", "teshu2"):
            cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                              n_heads=2, n_kv_heads=2, d_head=16, d_ff=64,
                              vocab=64, dtype="float32", remat=False,
                              moe=MoEConfig(num_experts=8, top_k=2,
                                            d_ff_expert=32, dispatch=disp,
                                            capacity_factor=8.0))
            p = init_moe(jax.random.key(7), cfg)
            x = jax.random.normal(jax.random.key(8), (4, 16, 32))
            with mesh:
                y_ref, _ = moe_ffn(p, cfg, x, mesh_axes=())
                y, _ = jax.jit(lambda p, x: moe_ffn(
                    p, cfg, x, mesh_axes=("pod", "model")))(p, x)
            err = float(jnp.max(jnp.abs(y - y_ref)))
            print(f"CHECK {disp} err={err:.2e} ok={err < 1e-5}")
    """)
    assert out.count("ok=True") == 2, out


def test_hier_psum_equals_flat():
    """Network-aware gradient template == flat all-reduce numerically; int8
    compression stays within quantization error."""
    out = run_sub("""
        from repro.core import meshops
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        x = jax.random.normal(jax.random.key(0), (64, 33))

        def run(mode, compress):
            def f(v):
                return meshops.grad_sync({"g": v}, inner_axis="data",
                                         outer_axis="pod", mode=mode,
                                         compress_outer=compress)["g"]
            from repro.compat import P, shard_map
            return jax.jit(shard_map(
                f, mesh=mesh, in_specs=P(), out_specs=P(),
                check_vma=False))(x)

        flat = run("flat", False)
        hier = run("hier", False)
        comp = run("hier", True)
        e1 = float(jnp.max(jnp.abs(flat - hier)))
        rel = float(jnp.max(jnp.abs(flat - comp)) / (jnp.max(jnp.abs(flat))))
        print(f"CHECK hier_exact={e1 < 1e-4} int8_close={rel < 0.02}",
              e1, rel)
    """)
    assert "hier_exact=True" in out and "int8_close=True" in out, out


def test_embed_lookup_sharded_matches_plain():
    out = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.models.lm import _embed_lookup
        mesh = make_mesh((2, 4), ("data", "model"))
        table = jax.random.normal(jax.random.key(1), (64, 32))
        toks = jax.random.randint(jax.random.key(2), (4, 6), 0, 64)
        with mesh:
            got = jax.jit(_embed_lookup)(table, toks)
        err = float(jnp.max(jnp.abs(got - table[toks])))
        print("CHECK", err < 1e-6)
    """)
    assert "CHECK True" in out


def test_train_step_under_mesh_runs_and_learns():
    """Two train steps on a (2,2,2) mesh with a scanned MoE smoke config."""
    out = run_sub("""
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import Recipe, make_train_step
        from repro.launch.shardings import param_specs, to_named, ep_axes_for
        from repro.configs import get_config
        from repro.models import lm
        from repro.optim import AdamWConfig, init_opt_state

        cfg = get_config("deepseek-v2-236b", smoke=True)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        with mesh:
            params = lm.init_lm(jax.random.key(0), cfg)
            opt = init_opt_state(params)
            step = make_train_step(cfg, AdamWConfig(lr=1e-2, warmup_steps=1,
                                                    total_steps=10),
                                   ep_axes_for(mesh), Recipe(n_micro=2))
            rng = np.random.default_rng(0)
            batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                           jnp.int32),
                     "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                           jnp.int32)}
            jstep = jax.jit(step, donate_argnums=(0, 1))
            losses = []
            for _ in range(3):
                params, opt, metrics = jstep(params, opt, batch)
                losses.append(float(metrics["loss"]))
        print("CHECK finite=", all(np.isfinite(losses)),
              "learns=", losses[-1] < losses[0], losses)
    """)
    assert "finite= True" in out and "learns= True" in out, out


def test_checkpoint_elastic_reshard():
    """Save on a (4,2) mesh, restore onto (2,2) — elastic mesh-reshape."""
    out = run_sub("""
        import tempfile
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        big = make_mesh((4, 2), ("data", "model"))
        small = make_mesh((2, 2), ("data", "model"))
        tree = {"w": jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(big, P("data", "model")))}
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, tree)
            target = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
            sh = {"w": NamedSharding(small, P("data", "model"))}
            restored, _ = cm.restore(target, sh)
        ok_val = bool(jnp.all(restored["w"] ==
                              jnp.arange(64, dtype=jnp.float32).reshape(8, 8)))
        ok_shard = restored["w"].sharding.mesh.shape == small.shape
        print("CHECK", ok_val and ok_shard)
    """)
    assert "CHECK True" in out


def test_train_driver_checkpoint_restart():
    """launch.train end-to-end: run 6 steps, kill, restart from step 4 —
    deterministic replay makes the loss history line up."""
    out = run_sub("""
        import tempfile, shutil
        from repro.launch.train import train
        with tempfile.TemporaryDirectory() as d:
            full = train("qwen2.5-14b", smoke=True, steps=6, global_batch=4,
                         seq_len=32, ckpt_dir=None, n_micro=1)
            part = train("qwen2.5-14b", smoke=True, steps=4, global_batch=4,
                         seq_len=32, ckpt_dir=d, ckpt_every=2, n_micro=1)
            resumed = train("qwen2.5-14b", smoke=True, steps=6, global_batch=4,
                            seq_len=32, ckpt_dir=d, ckpt_every=2, n_micro=1)
        f = [h["loss"] for h in full["history"]]
        r = [h["loss"] for h in resumed["history"]]
        # resumed covers steps 4..5; compare against the full run's tail
        err = max(abs(a - b) for a, b in zip(f[4:], r))
        print("CHECK", err < 5e-3, err, f, r)
    """, devices=4, timeout=1200)
    assert "CHECK True" in out


def test_elastic_mesh_factorizations():
    """elastic_mesh rebuilds the largest usable mesh after node loss."""
    out = run_sub("""
        from repro.launch.mesh import elastic_mesh
        m = elastic_mesh(32, model_parallel=4, pod_size=16)
        print("CHECK1", dict(m.shape))
        m2 = elastic_mesh(29, model_parallel=4, pod_size=16)   # 3 nodes lost
        print("CHECK2", dict(m2.shape))
    """, devices=32)
    assert "CHECK1 {'pod': 2, 'data': 4, 'model': 4}" in out, out
    assert "CHECK2" in out and "'model': 4" in out, out


def test_serve_driver_decodes():
    out = run_sub("""
        from repro.launch.serve import serve
        gen, stats = serve("granite-34b", smoke=True, batch=2, prompt_len=8,
                           gen_len=4, max_len=32)
        print("CHECK", gen.shape == (2, 4) and stats.tokens == 8)
    """, devices=4)
    assert "CHECK True" in out
