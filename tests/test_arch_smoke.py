"""Per-architecture smoke tests (assignment requirement f).

Every assigned arch instantiates its REDUCED config and runs one forward and
one train step on CPU, asserting output shapes and finite values; decode-capable
archs also run one serve_step against a cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.optim import AdamWConfig, adamw_update, init_opt_state

BATCH, SEQ = 2, 24


def _batch_for(cfg):
    rng = np.random.default_rng(0)
    out = {"labels": jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, SEQ)),
                                 jnp.int32)}
    if cfg.modality == "text":
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, SEQ)),
                                    jnp.int32)
    else:
        out["embeds"] = jnp.asarray(rng.standard_normal(
            (BATCH, SEQ, cfg.d_model)) * 0.02, jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_lm(jax.random.key(0), cfg)
    batch = _batch_for(cfg)

    logits, _, aux = lm.forward(params, cfg, tokens=batch.get("tokens"),
                                embeds=batch.get("embeds"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, grads = jax.value_and_grad(
        lambda p: lm.train_loss(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))

    # one optimizer step moves the loss
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
    p2, _, _ = adamw_update(ocfg, params, grads, init_opt_state(params))
    loss2 = lm.train_loss(p2, cfg, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_lm(jax.random.key(1), cfg)
    cache = lm.init_cache(cfg, BATCH, 32)
    if cfg.modality == "text":
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        logits, cache2 = lm.serve_step(params, cfg, cache, tokens=tok)
    else:
        emb = jnp.zeros((BATCH, 1, cfg.d_model), jnp.float32)
        logits, cache2 = lm.serve_step(params, cfg, cache, embeds=emb)
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "deepseek-v2-236b",
                                  "xlstm-350m", "hymba-1.5b"])
def test_prefill_matches_stepwise_decode(arch):
    """Prefill-then-decode == token-by-token decode (cache correctness)."""
    cfg = get_config(arch, smoke=True)
    params = lm.init_lm(jax.random.key(2), cfg)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)

    # path A: prefill 7 tokens, decode the 8th
    cache = lm.init_cache(cfg, 1, 16)
    _, cache, _ = lm.forward(params, cfg, tokens=toks[:, :7], cache=cache)
    logits_a, _ = lm.serve_step(params, cfg, cache, tokens=toks[:, 7:8])

    # path B: decode all 8 one by one
    cache = lm.init_cache(cfg, 1, 16)
    for i in range(8):
        logits_b, cache = lm.serve_step(params, cfg, cache,
                                        tokens=toks[:, i:i + 1])

    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=2e-2, atol=2e-3)


def test_param_counts_match_published():
    expect = {"llama3-405b": 405.8e9, "granite-34b": 34.0e9,
              "deepseek-v2-236b": 235.7e9, "qwen3-moe-235b-a22b": 235.0e9,
              "qwen2.5-14b": 14.8e9, "qwen1.5-110b": 111.2e9,
              "pixtral-12b": 12.2e9}
    for arch, n in expect.items():
        got = get_config(arch).num_params()
        assert abs(got - n) / n < 0.02, (arch, got, n)
