"""Durable shuffle storage: the write-behind spill store (PR 8).

Covers, per the acceptance criteria:

* the :class:`repro.core.storage.ShuffleStore` unit surface — serialization
  round trips, staging vs flushed backends, atomic per-tenant quotas,
  namespace teardown;
* **recovery-from-store**: with ``storage="durable"`` a mid-stage worker kill
  recovers by *reading* the surviving senders' persisted PART outputs — the
  journal shows no re-execution of surviving senders — byte-identical across
  the threaded / vectorized / jax executors, fresh and cache-hit;
* **streaming spill**: a session whose inflight bytes exceed ``max_inflight``
  completes via spill-to-store with bitwise-identical folds;
* the ledger's ``spill_bytes`` / ``restore_bytes`` lanes stay out of the
  exact byte-conformance keys;
* satellite regressions: O(own-keys) ``end_shuffle`` teardown, journal
  schema v2 with a pre-storage migration fixture, and direct
  CheckpointStore / StreamCheckpoint unit coverage.
"""
import dataclasses
import json
import os
import threading

import numpy as np
import pytest

from conformance import (EXECUTORS, assert_identical, copy_bufs, make_bufs,
                        make_topology, service_for)
from repro.core import Msgs, SUM, TeShuCluster, TeShuService
from repro.core.manager import JOURNAL_VERSION, ShuffleManager, ShuffleRecord
from repro.core.resilience import CheckpointStore
from repro.core.storage import (BlockKey, LocalDirBackend, MemoryBackend,
                                ShuffleStore, StorageContext, deserialize_msgs,
                                serialize_msgs)
from repro.launch import doctor

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")

SRCS = [0, 1, 2, 3]
DSTS = [4, 5, 6, 7]


def _bufs(seed=0, n=240, width=2):
    rng = np.random.default_rng(seed)
    return {w: Msgs(rng.integers(0, 500, n + 20 * w).astype(np.int64),
                    rng.random((n + 20 * w, width))) for w in SRCS}


# ---------------------------------------------------------------------------
# serialization + backends
# ---------------------------------------------------------------------------

def test_msgs_serialization_round_trips_bitwise():
    rng = np.random.default_rng(3)
    m = Msgs(rng.integers(0, 99, 57).astype(np.int64), rng.random((57, 3)))
    back = deserialize_msgs(serialize_msgs(m))
    np.testing.assert_array_equal(m.keys, back.keys)
    np.testing.assert_array_equal(m.vals, back.vals)
    # empty buffers keep their width through the wire
    e = deserialize_msgs(serialize_msgs(Msgs.empty(width=4)))
    assert e.n == 0 and e.width == 4


@pytest.mark.parametrize("backend_kind", ["memory", "dir"])
def test_backend_put_get_delete(tmp_path, backend_kind):
    be = (MemoryBackend() if backend_kind == "memory"
          else LocalDirBackend(str(tmp_path / "store")))
    k1 = BlockKey("a/b tenant", 7, "global", 0, 4)
    k2 = BlockKey("a/b tenant", 7, "stream", 1, 5, chunk=3)
    be.put(k1, b"xyz")
    be.put(k2, b"pq")
    assert be.get(k1) == b"xyz" and be.get(k2) == b"pq"
    assert be.get(BlockKey("a/b tenant", 7, "global", 0, 5)) is None
    be.delete_shuffle("a/b tenant", 7)
    assert be.get(k1) is None and be.get(k2) is None
    be.close()


# ---------------------------------------------------------------------------
# the store: staging, write-behind, quotas, teardown
# ---------------------------------------------------------------------------

def test_store_put_get_flush_and_drop():
    store = ShuffleStore(MemoryBackend(), write_behind=False)
    rng = np.random.default_rng(1)
    parts = {d: Msgs(rng.integers(0, 9, 10).astype(np.int64),
                     rng.random((10, 2))) for d in DSTS}
    assert store.put_parts("t", 5, "global", 0, parts)
    # staged blocks are readable before any flush
    got = store.get_block("t", 5, "global", 0, 4)
    np.testing.assert_array_equal(got.keys, parts[4].keys)
    assert store.has_block("t", 5, "global", 0, 7)
    assert store.block_bytes("t", 5, "global", 0, 7) > 0
    assert store.get_block("t", 5, "global", 1, 4) is None
    n = store.flush(5)
    assert n == len(DSTS)
    # flushed blocks still read back identically (now from the backend)
    got2 = store.get_block("t", 5, "global", 0, 4)
    np.testing.assert_array_equal(got2.vals, parts[4].vals)
    st = store.stats()
    assert st["flushed_blocks"] == len(DSTS) and st["staged_blocks"] == 0
    assert store.usage("t") > 0
    store.drop("t", 5)
    assert store.usage("t") == 0
    assert store.get_block("t", 5, "global", 0, 4) is None
    store.close()


def test_store_quota_is_atomic_all_or_none():
    store = ShuffleStore(MemoryBackend(), write_behind=False)
    rng = np.random.default_rng(2)
    parts = {d: Msgs(rng.integers(0, 9, 50).astype(np.int64),
                     rng.random((50, 2))) for d in DSTS}
    total = sum(len(serialize_msgs(m)) for m in parts.values())
    store.set_quota("t", total - 1)
    assert not store.put_parts("t", 5, "global", 0, parts)
    # nothing staged: the put is all-or-none
    assert store.usage("t") == 0
    assert all(store.get_block("t", 5, "global", 0, d) is None for d in DSTS)
    assert store.shuffle_stats("t", 5)["decline_reason"] == "quota_exceeded"
    store.set_quota("t", total)
    assert store.put_parts("t", 5, "global", 0, parts)
    assert store.usage("t") == total
    # overwrites are quota-checked on the delta, not the gross size
    assert store.put_parts("t", 5, "global", 0, parts)
    assert store.usage("t") == total
    # ...and another tenant is unaffected by "t"'s quota
    assert store.put_parts("u", 5, "global", 0, parts)
    store.close()


def test_store_discard_staged_drops_only_that_sender():
    store = ShuffleStore(MemoryBackend(), write_behind=False)
    m = {4: Msgs(np.arange(3, dtype=np.int64), np.ones((3, 1)))}
    store.put_parts("t", 9, "global", 0, m)
    store.put_parts("t", 9, "global", 1, m)
    store.flush(9)                       # worker 0's block is now durable
    store.put_parts("t", 9, "global", 0, m)   # re-staged (overwrite pending)
    assert store.discard_staged("t", 9, 1) == 0   # already flushed? no: 1 is
    # flushed too — only *staged* blocks are discarded
    assert store.discard_staged("t", 9, 0) == 1
    # the durable version written before the discard still serves
    assert store.get_block("t", 9, "global", 0, 4) is not None
    store.close()


def test_write_behind_flusher_lands_blocks_without_sync_flush():
    store = ShuffleStore(MemoryBackend(), write_behind=True)
    m = {4: Msgs(np.arange(8, dtype=np.int64), np.ones((8, 2)))}
    store.put_parts("t", 3, "global", 0, m)
    # flush() doubles as the barrier for the background thread
    store.flush(3)
    assert store.stats()["staged_blocks"] == 0
    assert store.backend.get(BlockKey("t", 3, "global", 0, 4)) is not None
    store.close()


def test_storage_knob_validation():
    with pytest.raises(ValueError):
        TeShuCluster(make_topology(), storage="bogus")
    cl = TeShuCluster(make_topology())
    with pytest.raises(ValueError):
        cl.tenant("a", storage="bogus")
    t = cl.tenant("a")
    with pytest.raises(ValueError):
        t.shuffle("vanilla_push", _bufs(), SRCS, DSTS, storage="bogus")
    with pytest.raises(ValueError):
        cl.tenant("b", storage_quota=0)


# ---------------------------------------------------------------------------
# the tentpole: durable recovery serves surviving senders from the store
# ---------------------------------------------------------------------------

def _run_durable(executor, *, fault, prime=False):
    sv = service_for(executor, resilience="recover", storage="durable")
    bufs = _bufs()
    if prime:
        sv.shuffle("vanilla_push", copy_bufs(bufs), SRCS, DSTS, comb_fn=SUM)
    if fault:
        sv.inject_fault(3, after_stage=-1)
    res = sv.shuffle("vanilla_push", copy_bufs(bufs), SRCS, DSTS, comb_fn=SUM)
    return sv, res


@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("prime", [False, True], ids=["fresh", "cache_hit"])
def test_durable_recovery_reads_survivors_from_store(executor, prime):
    _, base = _run_durable(executor, fault=False, prime=prime)
    sv, res = _run_durable(executor, fault=True, prime=prime)
    assert res.attempts == 2
    assert res.recovery["store_served"] == [0, 1, 2]
    assert_identical(res.bufs, base.bufs)
    # journal evidence: served senders ran NOTHING on the retry — no start
    # (hence no stage/end) records at attempt 1; only the dead sender and
    # the receivers re-ran
    sid = 2 if prime else 1
    starts1 = sorted({r.wid for r in sv.manager.records(sid, "start")
                      if r.attempt == 1})
    assert starts1 == [3] + DSTS
    restores = [r for r in sv.manager.records(sid, "restore")]
    assert restores and restores[0].info["served"] == [0, 1, 2]
    assert restores[0].info["restart_set"] == [3]


def test_durable_recovery_outputs_identical_across_executors():
    outs = []
    for ex in EXECUTORS:
        _, res = _run_durable(ex, fault=True, prime=True)
        outs.append(res.bufs)
    assert_identical(outs[0], outs[1])
    assert_identical(outs[0], outs[2])


def test_jax_declines_persisting_runs_with_reason():
    sv = service_for("jax", storage="durable")     # no recovery context
    bufs = _bufs()
    sv.shuffle("vanilla_push", copy_bufs(bufs), SRCS, DSTS, comb_fn=SUM)
    res = sv.shuffle("vanilla_push", copy_bufs(bufs), SRCS, DSTS, comb_fn=SUM)
    # the lowered kernel has no store hook: durable replay must land on the
    # byte-identical vectorized rung with a machine-checkable reason
    assert res.engine == "vectorized"
    assert res.fallback_reason == "storage_persist"
    # spill mode has no persistence contract: the jitted plane still runs it
    sv2 = service_for("jax", storage="spill")
    sv2.shuffle("vanilla_push", copy_bufs(bufs), SRCS, DSTS, comb_fn=SUM)
    hit = sv2.shuffle("vanilla_push", copy_bufs(bufs), SRCS, DSTS, comb_fn=SUM)
    assert hit.engine == "jax"
    assert_identical(hit.bufs, res.bufs)


def test_spill_lanes_stay_out_of_exact_conformance_stats():
    _, off = _run_durable("threaded", fault=False)
    sv, on = _run_durable("threaded", fault=False)
    assert on.stats.get("spill_bytes", 0) > 0          # durable run spilled
    assert on.stats["total_bytes"] == off.stats["total_bytes"]
    assert on.stats["recv_bytes_per_worker"] == off.stats["recv_bytes_per_worker"]
    # the epilogue dropped the namespace: the store holds nothing afterwards
    assert sv.store.usage("default") == 0


def test_durable_non_persistable_template_declines_cleanly():
    sv = service_for("threaded", storage="durable")
    workers = list(range(8))
    bufs = make_bufs(workers, "uniform", n=200)
    res = sv.shuffle("bruck", copy_bufs(bufs), workers, workers, comb_fn=SUM)
    base = service_for("threaded").shuffle(
        "bruck", copy_bufs(bufs), workers, workers, comb_fn=SUM)
    assert_identical(res.bufs, base.bufs)
    rep = sv.explain(1)
    assert rep.storage["decline"] == "template_not_persistable"
    assert any("no final per-(src, dst) partitions" in w for w in rep.why())


def test_storage_quota_decline_surfaces_in_explain():
    cl = TeShuCluster(make_topology(), resilience="recover", storage="durable")
    t = cl.tenant("tiny", storage_quota=8)       # nothing fits
    res = t.shuffle("vanilla_push", _bufs(), SRCS, DSTS, comb_fn=SUM)
    base = TeShuCluster(make_topology()).tenant("tiny").shuffle(
        "vanilla_push", _bufs(), SRCS, DSTS, comb_fn=SUM)
    assert_identical(res.bufs, base.bufs)        # declines never change bytes
    rep = cl.explain(1)
    assert rep.storage["decline_reason"] == "quota_exceeded"
    assert any("storage quota" in w for w in rep.why())


def test_storage_metrics_families_exported():
    sv, _ = _run_durable("threaded", fault=True, prime=False)
    snap = sv.metrics()
    assert "teshu_storage_puts_total" in snap
    assert "teshu_storage_flushed_bytes_total" in snap
    assert "teshu_storage_restored_bytes_total" in snap
    assert "teshu_spill_bytes_total" in snap


def test_doctor_reports_store_served_vs_reexecuted(tmp_path):
    journal = str(tmp_path / "journal.jsonl")
    sv = service_for("threaded", journal_path=journal, resilience="recover",
                     storage="durable")
    sv.inject_fault(3, after_stage=-1)
    res = sv.shuffle("vanilla_push", _bufs(), SRCS, DSTS, comb_fn=SUM)
    assert res.attempts == 2
    reports = doctor.diagnose(journal)
    assert reports[0]["restores"][0]["served"] == [0, 1, 2]
    assert reports[0]["spills"]                      # write-behind journaled
    text = doctor.render(reports)
    assert "3 sender(s) served from the store" in text
    assert "re-executed=[3]" in text


# ---------------------------------------------------------------------------
# streaming: a full window spills instead of folding early
# ---------------------------------------------------------------------------

def _stream(storage, *, quota=None, chunks=12, n=300):
    cl = TeShuCluster(make_topology(), storage=storage, chunk_bytes=2048,
                      max_inflight=2)
    t = (cl.tenant("app", storage_quota=quota) if quota is not None
         else cl.tenant("app"))
    s = t.open_stream("vanilla_push", SRCS, DSTS, comb_fn=SUM)
    rng = np.random.default_rng(7)
    for i in range(chunks):
        w = SRCS[i % len(SRCS)]
        s.feed({w: Msgs(rng.integers(0, 500, n).astype(np.int64),
                        rng.random((n, 2)))})
    return s.drain(), cl


def test_stream_spill_exceeds_window_with_identical_folds():
    off, _ = _stream("off")
    sp, cl = _stream("spill")
    assert sp["spilled"] > 0                 # inflight exceeded max_inflight
    assert sp["chunks"] == off["chunks"]
    assert_identical(sp["bufs"], off["bufs"])
    # spill/restore are charged on their own lanes; transfer bytes identical
    assert sp["stats"]["spill_bytes"] > 0
    assert sp["stats"]["spill_bytes"] == sp["stats"]["restore_bytes"]
    assert sp["stats"]["total_bytes"] == off["stats"]["total_bytes"]
    # modelled transfer time is untouched by spilling
    assert sp["stats"]["modelled_time_s"] == off["stats"]["modelled_time_s"]
    # drain() released the stream's namespace
    assert cl.store.usage("app") == 0


def test_stream_quota_decline_degrades_to_fold_early():
    off, _ = _stream("off")
    sp, cl = _stream("spill", quota=1)       # every put declines
    assert sp["spilled"] == 0
    assert_identical(sp["bufs"], off["bufs"])
    assert cl.store.stats()["declines"] > 0


# ---------------------------------------------------------------------------
# satellite 1: end_shuffle teardown is indexed per shuffle
# ---------------------------------------------------------------------------

def test_end_shuffle_clears_publish_boards_across_many_tenants():
    cl = TeShuCluster(make_topology())
    for i in range(12):
        t = cl.tenant(f"tenant-{i}")
        t.shuffle("vanilla_push", _bufs(seed=i), SRCS, DSTS, comb_fn=SUM)
    lc = cl.cluster
    assert lc._published == {} and lc._published_ev == {}
    assert lc._pub_index == {} and lc._rv_index == {}
    assert lc._rendezvous == {}


def test_end_shuffle_leaves_other_shuffles_keys_alone():
    cl = TeShuCluster(make_topology())
    lc = cl.cluster
    lc.publish((101, 0), "mine")
    lc.publish((202, 0), "other")
    lc.end_shuffle(101)
    assert (101, 0) not in lc._published
    assert lc._published[(202, 0)] == "other"
    assert 202 in lc._pub_index


# ---------------------------------------------------------------------------
# satellite 2: journal schema v2 + pre-storage migration
# ---------------------------------------------------------------------------

def test_journal_carries_storage_kinds():
    assert JOURNAL_VERSION >= 2
    rec = ShuffleRecord(-1, 4, "", "spill", 1.0, info={"blocks": 2,
                                                       "bytes": 99})
    d = json.loads(rec.to_json())
    assert d["v"] == JOURNAL_VERSION and d["kind"] == "spill"
    back = ShuffleRecord.from_json(rec.to_json())
    assert back.kind == "spill" and back.info == {"blocks": 2, "bytes": 99}


def test_pre_storage_journal_migrates(tmp_path):
    fixture = os.path.join(FIXTURES, "pre_storage_journal.jsonl")
    mgr = ShuffleManager.recover(fixture)
    recs = mgr.records()
    assert len(recs) == 8
    assert {r.version for r in recs} == {1}      # v1 provenance preserved
    assert mgr.progress(1) == {"started": [0, 1], "finished": [0, 1],
                               "pending": []}
    assert mgr.recovery_records(2)[0].info["restarted"] == [3]
    # a mixed journal — pre-storage lines plus v2 spill/restore records —
    # replays cleanly end to end
    mixed = tmp_path / "mixed.jsonl"
    lines = open(fixture).read().splitlines()
    lines.append(json.dumps(
        {"wid": -1, "shuffle_id": 3, "template_id": "", "kind": "spill",
         "ts": 12.0, "v": 2, "tenant": "ml", "info": {"blocks": 4,
                                                      "bytes": 512}}))
    lines.append(json.dumps(
        {"wid": -1, "shuffle_id": 3, "template_id": "", "kind": "restore",
         "ts": 12.1, "v": 2, "attempt": 1, "tenant": "ml",
         "info": {"served": [0, 1], "blocks": 8, "bytes": 1024,
                  "restart_set": [2]}}))
    mixed.write_text("\n".join(lines) + "\n")
    mgr2 = ShuffleManager.recover(str(mixed))
    spills = [r for r in mgr2.records(3) if r.kind == "spill"]
    restores = [r for r in mgr2.records(3) if r.kind == "restore"]
    assert spills[0].info["blocks"] == 4
    assert restores[0].info["served"] == [0, 1]


# ---------------------------------------------------------------------------
# satellite 3: CheckpointStore / StreamCheckpoint direct unit coverage
# ---------------------------------------------------------------------------

def test_checkpoint_store_copies_and_scopes_by_shuffle():
    cs = CheckpointStore()
    m = Msgs(np.arange(4, dtype=np.int64), np.ones((4, 2)))
    cs.save(1, 0, 0, "server", m)
    m.vals[:] = -1                                  # caller aliasing
    loaded = cs.load(1, 0, 0)
    assert float(loaded.vals.sum()) == 8.0          # snapshot unaffected
    loaded.vals[:] = -1
    assert float(cs.load(1, 0, 0).vals.sum()) == 8.0   # loads are copies too
    assert cs.load(2, 0, 0) is None                 # shuffle-scoped
    assert cs.last_stage(1, 0) == 0 and cs.last_stage(1, 9) == -1
    cs.save(1, 0, 1, "rack", m)
    assert cs.stages(1) == {0: 1}
    st = cs.stats()
    assert st["shuffles"] == 1 and st["checkpoints"] == 2
    cs.clear(1)
    assert cs.load(1, 0, 0) is None and cs.stats()["checkpoints"] == 0


def test_stream_checkpoint_cursor_round_trip():
    cs = CheckpointStore()
    acc = Msgs(np.arange(3, dtype=np.int64), np.zeros((3, 1)))
    cs.save_stream(5, 4, "global", peer_idx=2, folded=7, pre_bytes=99,
                   acc=acc)
    acc.vals[:] = 1.0
    ck = cs.load_stream(5, 4, "global")
    assert (ck.peer_idx, ck.folded, ck.pre_bytes) == (2, 7, 99)
    assert float(ck.acc.vals.sum()) == 0.0          # snapshot isolated
    assert cs.load_stream(5, 4, "rack") is None     # tag-scoped
    assert cs.load_stream(6, 4, "global") is None   # shuffle-scoped
    cs.save_stream(5, 4, "global", peer_idx=3, folded=0, pre_bytes=0,
                   acc=None)
    assert cs.load_stream(5, 4, "global").acc is None
    assert cs.stats()["stream_checkpoints"] == 1
    cs.clear(5)
    assert cs.load_stream(5, 4, "global") is None


# ---------------------------------------------------------------------------
# concurrency: parallel tenants through one store
# ---------------------------------------------------------------------------

def test_parallel_tenants_share_the_store_safely():
    store = ShuffleStore(MemoryBackend(), write_behind=True)
    errs = []

    def worker(tenant, sid):
        try:
            rng = np.random.default_rng(sid)
            for src in range(4):
                parts = {d: Msgs(rng.integers(0, 9, 20).astype(np.int64),
                                 rng.random((20, 1))) for d in DSTS}
                store.put_parts(tenant, sid, "global", src, parts)
            store.flush(sid)
            for src in range(4):
                for d in DSTS:
                    if store.get_block(tenant, sid, "global", src, d) is None:
                        raise AssertionError((tenant, sid, src, d))
            store.drop(tenant, sid)
            if store.usage(tenant) != 0:
                raise AssertionError(f"{tenant} usage leak")
        except Exception as e:  # noqa: BLE001 — surfaced to the main thread
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(f"t{i}", i))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert store.stats()["staged_blocks"] == 0
    store.close()


def test_storage_context_is_frozen_and_defaults_off():
    ctx = StorageContext(None, "spill", "t")
    assert not ctx.persist and ctx.min_stages == 0 and ctx.decline is None
    with pytest.raises(dataclasses.FrozenInstanceError):
        ctx.persist = True
