"""Skew-aware template instantiation (ISSUE 3 acceptance).

The contract: on a Zipf(1.2) workload, ``balance="auto"`` cuts the max
per-destination received bytes by >= 2x vs ``balance="off"`` (asserted via the
CostLedger's per-destination accounting) while keeping outputs correct; a
uniform workload triggers no rebalance and stays byte-identical to the
``balance="off"`` path on both executors; rebalanced plans hit the cache on
repeat calls (bitwise-identical replays, threaded and vectorized); and a
worker kill is survived via plan repair that re-targets hot-key splits onto
surviving destinations.
"""
import numpy as np
import pytest

from conformance import WORKERS, assert_msgs_sorted_identical as _sorted_eq, \
    copy_bufs as _copy, make_bufs, zipf_keys
from repro.core import (HASH_PART, SUM, HeavyHitterSketch, Msgs, PlanCache,
                        TeShuService, datacenter, dst_load_imbalance,
                        local_skew_stats, merge_skew_stats, owner_merge_plan,
                        plan_rebalance, scatter_part_fn, skew_bucket,
                        stats_signature)

TOPO = lambda: datacenter(4, 2, 1)          # 8 workers, server < rack hierarchy


def zipf_bufs(nw=8, n_per=8000, keys=500, alpha=1.2, seed=0, identical=False):
    """Zipf(alpha) keyed buffers; ``identical=True`` gives every worker the
    same key multiset (participant-subset signatures then match exactly,
    which is what the lost-worker repair path keys on)."""
    rng = np.random.default_rng(seed)
    if identical:
        ks = zipf_keys(rng, n_per, keys, alpha)
        return {wid: Msgs(ks.copy(), rng.random((n_per, 1)))
                for wid in range(nw)}
    return {wid: Msgs(zipf_keys(rng, n_per, keys, alpha),
                      rng.random((n_per, 1)))
            for wid in range(nw)}


def uniform_bufs(nw=8, n_per=8000, keys=5000, seed=0):
    return make_bufs(range(nw), "uniform", n=n_per, key_space=keys,
                     width=1, seed=seed)


def _check_totals(inputs: dict[int, Msgs], res):
    """Global invariant of a combined shuffle: pooling every output equals
    combining every input, and no key lands on two destinations."""
    ref = SUM(Msgs.concat(list(inputs.values())))
    allout = Msgs.concat([res.bufs[w] for w in sorted(res.bufs)])
    assert allout.n == np.unique(allout.keys).size     # owner-merge completed
    got = SUM(allout)
    oa, ob = np.argsort(ref.keys), np.argsort(got.keys)
    np.testing.assert_array_equal(ref.keys[oa], got.keys[ob])
    np.testing.assert_allclose(ref.vals[oa], got.vals[ob], rtol=1e-12)


def _max_recv(res, dsts):
    recv = res.stats["recv_bytes_per_worker"]
    return max(recv.get(d, 0) for d in dsts)


# ---------------------------------------------------------------------------
# sketch + decision units
# ---------------------------------------------------------------------------

def test_sketch_exact_under_capacity_and_bounded_over():
    keys = np.repeat(np.arange(20, dtype=np.int64), np.arange(1, 21))
    sk = HeavyHitterSketch.from_keys(keys, capacity=64)
    assert sk.total == keys.size and sk.error_bound == 0
    assert dict(sk.top()) == {k: k + 1 for k in range(20)}     # exact
    tight = HeavyHitterSketch.from_keys(keys, capacity=4)
    assert len(tight) <= 4
    assert tight.error_bound <= keys.size // 4                 # MG guarantee
    # the heaviest key survives compression and is undercounted <= error_bound
    top_key, top_cnt = tight.top(1)[0]
    assert top_key == 19 and 20 - tight.error_bound <= top_cnt <= 20


def test_sketch_merge_preserves_heavy_hitters():
    rng = np.random.default_rng(0)
    shards = [np.concatenate([np.full(500, 7, dtype=np.int64),
                              rng.integers(100, 5000, 2000)]) for _ in range(4)]
    merged = HeavyHitterSketch.from_keys(shards[0], capacity=32)
    for s in shards[1:]:
        merged = merged.merge(HeavyHitterSketch.from_keys(s, capacity=32))
    assert merged.total == sum(s.size for s in shards)
    top_key, top_cnt = merged.top(1)[0]
    assert top_key == 7
    assert 2000 - merged.error_bound <= top_cnt <= 2000


def test_rebalance_triggers_on_skew_not_on_uniform():
    for bufs, expect in ((zipf_bufs(), True), (uniform_bufs(), False)):
        stats = [local_skew_stats(m, HASH_PART, 8) for m in bufs.values()]
        sketch, loads = merge_skew_stats(stats)
        dec = plan_rebalance(sketch, loads, HASH_PART, 8)
        assert dec.triggered == expect, (expect, dec.est_imbalance)
        if expect:
            assert dec.est_balanced_imbalance < dec.est_imbalance / 1.5
            # every hot key is split across >= 2 distinct in-range slots
            for k, share in dec.splits:
                assert len(share) >= 2 and len(set(share)) == len(share)
                assert all(0 <= s < 8 for s in share)


def test_rebalance_deterministic_across_merge_orders():
    bufs = zipf_bufs(seed=5)
    stats = [local_skew_stats(m, HASH_PART, 8) for m in bufs.values()]
    s1, l1 = merge_skew_stats(stats)
    s2, l2 = merge_skew_stats(list(reversed(stats)))
    d1 = plan_rebalance(s1, l1, HASH_PART, 8)
    d2 = plan_rebalance(s2, l2, HASH_PART, 8)
    assert d1.splits == d2.splits


def test_scatter_part_fn_cycles_hot_keys_and_passes_through():
    bufs = zipf_bufs(seed=1)
    stats = [local_skew_stats(m, HASH_PART, 8) for m in bufs.values()]
    dec = plan_rebalance(*merge_skew_stats(stats), HASH_PART, 8)
    assert dec.triggered
    fn = scatter_part_fn(HASH_PART, dec)
    keys = bufs[0].keys
    base = HASH_PART.assign(keys, 8)
    out = fn.assign(keys, 8)
    hot = dec.split_keys()
    cold = ~np.isin(keys, hot)
    np.testing.assert_array_equal(out[cold], base[cold])       # cold untouched
    for k, share in dec.splits:
        idx = np.nonzero(keys == k)[0]
        if idx.size:
            want = np.asarray(share)[np.arange(idx.size) % len(share)]
            np.testing.assert_array_equal(out[idx], want)      # cycle, in order
    # a different slot-space width (a local exchange) is never scattered
    np.testing.assert_array_equal(fn.assign(keys, 4), HASH_PART.assign(keys, 4))


def test_owner_merge_plan_owners_and_sharers_disjoint():
    bufs = zipf_bufs(seed=2)
    stats = [local_skew_stats(m, HASH_PART, 8) for m in bufs.values()]
    dec = plan_rebalance(*merge_skew_stats(stats), HASH_PART, 8)
    merge = owner_merge_plan(dec, HASH_PART, tuple(WORKERS))
    assert merge
    seen = set()
    for owner, (okeys, sharers) in merge.items():
        assert owner not in sharers
        assert not (set(okeys.tolist()) & seen)                # one owner per key
        seen |= set(okeys.tolist())
    assert seen == set(dec.split_keys().tolist())


# ---------------------------------------------------------------------------
# signature: skewed vs uniform epochs never alias
# ---------------------------------------------------------------------------

def test_skew_bucket_separates_zipf_from_uniform():
    assert skew_bucket(zipf_bufs()) > skew_bucket(uniform_bufs())
    # flat distributions of different sizes all clamp to the floor bucket
    assert skew_bucket(uniform_bufs(keys=500)) == skew_bucket(uniform_bufs(keys=50000))


def test_signature_splits_on_balance_and_skew():
    # same shape (counts, widths, key space), different skew: under auto the
    # skew bucket separates them; off mode skips the extra hashing pass
    rng = np.random.default_rng(0)
    keys = 5000
    u = uniform_bufs(keys=keys)
    z = {w: Msgs(m.keys.copy(), m.vals.copy()) for w, m in u.items()}
    for w, m in z.items():
        m.keys[: m.n // 5] = keys - 1          # 20% of traffic on one key
    assert stats_signature(z, HASH_PART, SUM, 0.05, balance="auto") != \
        stats_signature(u, HASH_PART, SUM, 0.05, balance="auto")
    assert stats_signature(z, HASH_PART, SUM, 0.05) == \
        stats_signature(u, HASH_PART, SUM, 0.05)   # off: no skew component
    assert stats_signature(z, HASH_PART, SUM, 0.05, balance="auto") != \
        stats_signature(z, HASH_PART, SUM, 0.05, balance="off")


# ---------------------------------------------------------------------------
# acceptance: >= 2x tail-load reduction, correctness, cache, both executors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("execution", ["auto", "threaded"])
def test_zipf_auto_halves_max_received_bytes(execution):
    bufs = zipf_bufs()
    results = {}
    for balance in ("off", "auto"):
        svc = TeShuService(TOPO(), balance=balance)
        res = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                          comb_fn=SUM, rate=0.05, execution=execution)
        _check_totals(bufs, res)
        results[balance] = res
    assert "rebalance" not in dict(results["off"].decisions)
    dec = dict(results["auto"].decisions)["rebalance"]
    assert dec.triggered and dec.est_imbalance > 2.0
    off_max = _max_recv(results["off"], WORKERS)
    auto_max = _max_recv(results["auto"], WORKERS)
    assert off_max >= 2.0 * auto_max, (off_max, auto_max)
    assert dst_load_imbalance(results["auto"].stats, WORKERS) < 1.3


def test_uniform_auto_is_byte_identical_to_off():
    bufs = uniform_bufs()
    outs = {}
    for balance in ("off", "auto"):
        for execution in ("auto", "threaded"):
            svc = TeShuService(TOPO(), balance=balance)
            fresh = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                                comb_fn=SUM, rate=0.05, execution=execution)
            hit = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                              comb_fn=SUM, rate=0.05, execution=execution)
            assert not fresh.cached and hit.cached
            outs[(balance, execution)] = (fresh, hit)
    dec = dict(outs[("auto", "auto")][0].decisions)["rebalance"]
    assert not dec.triggered                       # estimate kept, no splits
    ref_fresh, ref_hit = outs[("off", "threaded")]
    for (balance, _), (fresh, hit) in outs.items():
        for w in ref_fresh.bufs:                   # outputs identical, always
            _sorted_eq(ref_fresh.bufs[w], fresh.bufs[w])
            _sorted_eq(ref_fresh.bufs[w], hit.bufs[w])
        # the fresh run's only extra traffic vs balance=off is the sketch
        # shipment, accounted as sampling overhead (Figure-6 semantics) ...
        data_bytes = fresh.stats["total_bytes"] - fresh.stats["sample_bytes"]
        assert data_bytes == \
            ref_fresh.stats["total_bytes"] - ref_fresh.stats["sample_bytes"]
        assert (fresh.stats["sample_bytes"] > 0) == (balance == "auto")
        # ... and replays skip the gather: byte-identical ledgers throughout
        assert hit.stats["bytes_per_level"] == ref_hit.stats["bytes_per_level"]
        assert hit.stats["total_bytes"] == ref_hit.stats["total_bytes"]
        assert hit.stats["sample_bytes"] == 0


@pytest.mark.parametrize("template", ["vanilla_push", "vanilla_pull",
                                      "coordinated", "bruck", "network_aware"])
def test_rebalanced_plan_cached_and_replays_identically(template):
    bufs = zipf_bufs(n_per=4000, seed=3)
    svc = TeShuService(TOPO(), balance="auto")
    fresh = svc.shuffle(template, _copy(bufs), WORKERS, WORKERS,
                        comb_fn=SUM, rate=0.05)
    assert not fresh.cached
    assert dict(fresh.decisions)["rebalance"].triggered
    vec = svc.shuffle(template, _copy(bufs), WORKERS, WORKERS,
                      comb_fn=SUM, rate=0.05)
    thr = svc.shuffle(template, _copy(bufs), WORKERS, WORKERS,
                      comb_fn=SUM, rate=0.05, execution="threaded")
    assert vec.cached and thr.cached
    if template != "bruck":
        assert vec.vectorized
    st = svc.cache_stats()
    assert st["misses"] == 1 and st["hits"] == 2 and st["invalidations"] == 0
    # replays report the frozen rebalance verdict and stay bitwise identical
    assert dict(vec.decisions)["rebalance"].splits == \
        dict(fresh.decisions)["rebalance"].splits
    for w in fresh.bufs:
        _sorted_eq(fresh.bufs[w], vec.bufs[w])
        _sorted_eq(fresh.bufs[w], thr.bufs[w])
    assert vec.stats["recv_bytes_per_worker"] == thr.stats["recv_bytes_per_worker"]
    _check_totals(bufs, vec)


def test_skew_threshold_is_part_of_the_plan_key():
    """A plan frozen under one rebalance trigger point must not serve a call
    that asked for a different one."""
    bufs = zipf_bufs(n_per=4000, seed=6)       # est_imbalance ~2.5
    svc = TeShuService(TOPO(), balance="auto")
    lax = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                      comb_fn=SUM, rate=0.05, skew_threshold=10.0)
    assert not dict(lax.decisions)["rebalance"].triggered
    strict = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                         comb_fn=SUM, rate=0.05, skew_threshold=1.2)
    assert not strict.cached                   # different threshold -> miss
    assert dict(strict.decisions)["rebalance"].triggered
    assert svc.cache_stats()["hits"] == 0


def test_non_rebalanceable_template_resolves_to_off_keying():
    """two_level can never carry a skew decision, so balance=auto must not
    pay the skew-bucket pass or split its plans across skew epochs: the same
    workload hits the same plan whichever balance mode the caller asked for."""
    topo = datacenter(4, 2, 2)
    workers = list(range(16))
    bufs = zipf_bufs(nw=16, n_per=2000, seed=9)
    svc = TeShuService(topo)
    first = svc.shuffle("two_level", _copy(bufs), workers, workers,
                        comb_fn=SUM, rate=0.05, balance="auto")
    assert not first.cached
    second = svc.shuffle("two_level", _copy(bufs), workers, workers,
                         comb_fn=SUM, rate=0.05, balance="off")
    assert second.cached                       # same key either way


def test_two_level_declines_rebalance_but_stays_correct():
    topo = datacenter(4, 2, 2)                 # 16 workers: square grid
    workers = list(range(16))
    bufs = zipf_bufs(nw=16, n_per=3000, seed=2)
    svc = TeShuService(topo, balance="auto")
    fresh = svc.shuffle("two_level", _copy(bufs), workers, workers,
                        comb_fn=SUM, rate=0.05)
    assert "rebalance" not in dict(fresh.decisions)
    hit = svc.shuffle("two_level", _copy(bufs), workers, workers,
                      comb_fn=SUM, rate=0.05)
    assert hit.cached
    for w in fresh.bufs:
        _sorted_eq(fresh.bufs[w], hit.bufs[w])


def test_load_drift_invalidates_stale_plan():
    """A hot key appearing under a plan compiled on near-uniform data (same
    signature bucket) drifts the observed per-destination loads -> the plan is
    dropped and the next call re-instantiates with splits."""
    uniform = uniform_bufs(n_per=4000, keys=3000, seed=1)
    hotted = {}
    rng = np.random.default_rng(1)
    for w in range(8):
        ks = rng.integers(0, 3000, 4000).astype(np.int64)
        ks[:400] = 7                          # ~10% of traffic on one key
        hotted[w] = Msgs(ks, rng.random((4000, 1)))
    assert stats_signature(uniform, HASH_PART, SUM, 0.05, balance="auto") == \
        stats_signature(hotted, HASH_PART, SUM, 0.05, balance="auto")
    svc = TeShuService(TOPO(), balance="auto")
    first = svc.shuffle("vanilla_push", _copy(uniform), WORKERS, WORKERS,
                        comb_fn=SUM, rate=0.05)
    assert not dict(first.decisions)["rebalance"].triggered
    drifted = svc.shuffle("vanilla_push", _copy(hotted), WORKERS, WORKERS,
                          comb_fn=SUM, rate=0.05)
    assert drifted.cached                     # same key -> hit ...
    assert svc.cache_stats()["invalidations"] == 1   # ... but loads drifted
    again = svc.shuffle("vanilla_push", _copy(hotted), WORKERS, WORKERS,
                        comb_fn=SUM, rate=0.05)
    assert not again.cached
    assert dict(again.decisions)["rebalance"].triggered


def test_steady_zipf_replays_do_not_drift():
    svc = TeShuService(TOPO(), balance="auto")
    bufs = zipf_bufs(n_per=4000, seed=4)
    svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                comb_fn=SUM, rate=0.05)
    for seed in (5, 6, 7):                    # same distribution, fresh draws
        more = zipf_bufs(n_per=4000, seed=seed)
        svc.shuffle("vanilla_push", _copy(more), WORKERS, WORKERS,
                    comb_fn=SUM, rate=0.05)
    st = svc.cache_stats()
    assert st["invalidations"] == 0 and st["hits"] == 3


# ---------------------------------------------------------------------------
# resilience: worker kill -> plan repair re-targets the splits
# ---------------------------------------------------------------------------

def test_worker_kill_survived_via_retargeted_repair():
    bufs = zipf_bufs(identical=True, n_per=6000)
    cache = PlanCache()
    svc = TeShuService(TOPO(), plan_cache=cache, balance="auto",
                       resilience="recover")
    full = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                       comb_fn=SUM, rate=0.05)
    assert dict(full.decisions)["rebalance"].triggered

    svc.fail_worker(3)
    survivors = [w for w in WORKERS if w != 3]
    sub = {w: bufs[w].copy() for w in survivors}
    res = svc.shuffle("vanilla_push", sub, survivors, survivors,
                      comb_fn=SUM, rate=0.05)
    assert res.repaired and res.cached
    assert cache.stats()["repairs"] == 1
    dec = dict(res.decisions)["rebalance"]
    assert dec.triggered
    # every split share and owner is a surviving destination
    touched = {survivors[s] for _, share in dec.splits for s in share}
    owners = set(owner_merge_plan(dec, HASH_PART, tuple(survivors)))
    assert 3 not in touched and 3 not in owners
    _check_totals(sub, res)
    # the SAME degraded scenario again is a plain cache hit, no second repair
    again = svc.shuffle("vanilla_push", _copy(sub), survivors, survivors,
                        comb_fn=SUM, rate=0.05)
    assert again.cached and not again.repaired
    assert cache.stats()["repairs"] == 1
    for w in res.bufs:
        _sorted_eq(res.bufs[w], again.bufs[w])


def test_mid_shuffle_kill_recovers_on_rebalanced_plan():
    """A fault injected mid-shuffle under balance=auto: the recovery retry
    replays the frozen rebalance and still produces correct totals."""
    bufs = zipf_bufs(n_per=3000, seed=8)
    svc = TeShuService(TOPO(), balance="auto", resilience="recover")
    warm = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                       comb_fn=SUM, rate=0.05)
    assert dict(warm.decisions)["rebalance"].triggered
    svc.inject_fault(5, after_stage=-1)
    res = svc.shuffle("vanilla_push", _copy(bufs), WORKERS, WORKERS,
                      comb_fn=SUM, rate=0.05)
    svc.clear_fault(5)
    assert res.attempts > 1
    _check_totals(bufs, res)
    for w in warm.bufs:
        _sorted_eq(warm.bufs[w], res.bufs[w])
