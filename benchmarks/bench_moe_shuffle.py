"""Beyond-paper integration: the shuffle layer inside an LM training step.

Two experiments, both measured from compiled HLO (loop-aware analyzer) on an
8-device (2 pod x 2 data x 2 model) host mesh:

* **gradient sync**: flat all-reduce vs the network-aware hierarchical
  template (reduce-scatter inner / all-reduce outer / all-gather), with and
  without int8 cross-pod compression — DCN wire bytes per step.
* **MoE dispatch**: vanilla single-level all-to-all over (pod, model) vs the
  two-level exchange template — DCN wire bytes per dispatch.
"""
from __future__ import annotations

import numpy as np

from .common import CsvOut


def grad_sync_bytes() -> CsvOut:
    import jax
    import jax.numpy as jnp
    from repro.core import meshops
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_mesh

    out = CsvOut("grad_sync_templates",
                 ["mode", "ici_mb", "dcn_mb", "total_mb"])
    ndev = len(jax.devices())
    if ndev < 8:
        out.add(mode=f"skipped (needs 8 devices, have {ndev})",
                ici_mb=0.0, dcn_mb=0.0, total_mb=0.0)
        return out
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    grads = {"w1": jnp.ones((1024, 1024)), "w2": jnp.ones((4096, 256))}

    def run(mode, compress):
        def f(g):
            return jax.shard_map(
                lambda t: jax.tree.map(
                    lambda x: meshops.grad_sync(
                        {"g": x}, inner_axis="data",
                        outer_axis="pod", mode=mode,
                        compress_outer=compress)["g"], t),
                mesh=mesh, in_specs=jax.P(), out_specs=jax.P(),
                check_vma=False)(g)
        compiled = jax.jit(f).lower(grads).compile()
        cost = analyze_hlo(compiled.as_text(), pod_size=4)
        return cost

    for mode, compress, label in (("flat", False, "flat_allreduce"),
                                  ("hier", False, "hier_rs_ar_ag"),
                                  ("hier", True, "hier_int8_crosspod")):
        c = run(mode, compress)
        out.add(mode=label, ici_mb=c.ici_bytes / 1e6, dcn_mb=c.dcn_bytes / 1e6,
                total_mb=(c.ici_bytes + c.dcn_bytes) / 1e6)
    return out


def moe_dispatch_bytes() -> CsvOut:
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_mesh
    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.moe import init_moe, moe_ffn

    out = CsvOut("moe_dispatch_templates",
                 ["dispatch", "ici_mb", "dcn_mb", "a2a_count"])
    ndev = len(jax.devices())
    if ndev < 8:
        out.add(dispatch=f"skipped (needs 8 devices, have {ndev})",
                ici_mb=0.0, dcn_mb=0.0, a2a_count=0)
        return out
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    for disp in ("teshu", "teshu2"):
        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=256,
                          n_heads=4, n_kv_heads=4, d_head=64, d_ff=512,
                          vocab=1024, dtype="float32", remat=False,
                          moe=MoEConfig(num_experts=16, top_k=2,
                                        d_ff_expert=256, dispatch=disp,
                                        capacity_factor=1.5))
        p = init_moe(jax.random.key(0), cfg)
        x = jnp.ones((8, 128, 256))
        with mesh:
            compiled = jax.jit(
                lambda p, x: moe_ffn(p, cfg, x,
                                     mesh_axes=("pod", "model"))[0]
            ).lower(p, x).compile()
        cost = analyze_hlo(compiled.as_text(), pod_size=4)
        a2a = sum(v for (op, _), v in cost.by_op.items() if op == "all-to-all")
        out.add(dispatch=disp, ici_mb=cost.ici_bytes / 1e6,
                dcn_mb=cost.dcn_bytes / 1e6,
                a2a_count=int(cost.collective_count))
    return out


def _rerun_with_devices() -> str | None:
    """The parent process may have initialized jax with 1 device; these
    experiments need 8 — re-exec this module in a fresh subprocess."""
    import jax
    if len(jax.devices()) >= 8:
        return None
    import os
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(root, "src") + ":" + root)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_moe_shuffle"],
        capture_output=True, text=True, timeout=1200, env=env, cwd=root)
    if out.returncode != 0:
        raise RuntimeError(f"subprocess failed:\n{out.stderr[-2000:]}")
    return out.stdout


def run() -> list[CsvOut]:
    sub = _rerun_with_devices()
    if sub is not None:
        print(sub, end="")
        return []
    return [grad_sync_bytes(), moe_dispatch_bytes()]


if __name__ == "__main__":
    for t in run():
        t.emit()
