"""Shared benchmark infrastructure: workloads, topologies, CSV emission."""
from __future__ import annotations

import csv
import io
import sys

import numpy as np

from repro.core import Msgs, TeShuService, datacenter


def paper_topology(oversubscription: float = 10.0, *, workers_per_server=4,
                   servers_per_rack=5, racks=2) -> "datacenter":
    """Container-scale analogue of the paper's testbed: 2 racks x 10 servers
    (4 workers each here instead of 16 cores), 10 Gbps fabric, parameterized
    oversubscription (10:1 / 4:1 / 1:1 per Table 4)."""
    return datacenter(workers_per_server, servers_per_rack, racks,
                      intra_server_bw=12.5e9, intra_rack_bw=1.25e9,
                      oversubscription=oversubscription)


def zipf_shards(nw: int, n_per: int, keys: int, *, alpha: float = 0.9,
                width: int = 1, seed: int = 0) -> dict[int, Msgs]:
    """Power-law keyed message buffers (web/social-graph stand-in)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, keys + 1, dtype=np.float64)
    w = ranks ** -alpha
    cdf = np.cumsum(w) / np.sum(w)
    return {
        wid: Msgs(np.searchsorted(cdf, rng.random(n_per)).astype(np.int64),
                  rng.random((n_per, width)))
        for wid in range(nw)
    }


class CsvOut:
    """Collects rows and prints one CSV block per benchmark."""

    def __init__(self, name: str, fields: list[str]):
        self.name = name
        self.fields = fields
        self.rows: list[dict] = []

    def add(self, **row) -> None:
        self.rows.append(row)

    def emit(self, file=sys.stdout) -> None:
        print(f"\n# === {self.name} ===", file=file)
        w = csv.DictWriter(file, fieldnames=self.fields)
        w.writeheader()
        for r in self.rows:
            w.writerow({k: (f"{v:.4g}" if isinstance(v, float) else v)
                        for k, v in r.items()})
