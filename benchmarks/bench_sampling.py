"""Figure 5 + Figure 6: partition-aware vs random sampling accuracy, and the
accuracy/overhead tradeoff across sampling rates."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (SUM, Msgs, estimate_reduction_ratio,
                        partition_aware_sample, random_sample, reduction_ratio)

from .common import CsvOut, zipf_shards

RATES = (0.9, 0.1, 0.01, 0.001, 0.0001)


def figure5(n_workers=8, n_per=800_000, keys=600_000, seeds=3) -> CsvOut:
    """Reduction-ratio estimation: ground truth vs random vs partition-aware.

    Key space and message counts are scaled so that even at rate 1e-4 a sampled
    group holds ~20 keys x all their occurrences (the paper's billion-edge
    graphs keep groups large at much lower rates)."""
    out = CsvOut("figure5_sampling_accuracy",
                 ["rate", "ground_truth", "random", "part_aware"])
    shards = zipf_shards(n_workers, n_per, keys, alpha=0.9)
    truth = reduction_ratio(Msgs.concat(list(shards.values())), SUM)
    for rate in RATES:
        rnd, pa = [], []
        for s in range(seeds):
            rnd.append(reduction_ratio(Msgs.concat(
                [random_sample(m, rate, seed=s) for m in shards.values()]), SUM))
            pa.append(estimate_reduction_ratio(
                [partition_aware_sample(m, rate, seed=s)
                 for m in shards.values()], SUM))
        out.add(rate=rate, ground_truth=truth, random=float(np.mean(rnd)),
                part_aware=float(np.mean(pa)))
    return out


def figure6(n_workers=8, n_per=500_000, keys=400_000) -> CsvOut:
    """Accuracy vs overhead: sampled fraction of bytes (the shuffle-plan
    overhead proxy) and |estimate - truth| accuracy per rate."""
    out = CsvOut("figure6_accuracy_vs_overhead",
                 ["rate", "accuracy", "overhead_frac", "est", "truth"])
    shards = zipf_shards(n_workers, n_per, keys, alpha=0.9, seed=1)
    total_bytes = sum(m.nbytes for m in shards.values())
    truth = reduction_ratio(Msgs.concat(list(shards.values())), SUM)
    for rate in (0.1, 0.05, 0.01, 0.001, 0.0001):
        samples = [partition_aware_sample(m, rate, seed=2)
                   for m in shards.values()]
        est = estimate_reduction_ratio(samples, SUM)
        overhead = sum(s.nbytes for s in samples) / total_bytes
        acc = max(0.0, 1.0 - abs(est - truth) / max(truth, 1e-9))
        out.add(rate=rate, accuracy=acc, overhead_frac=overhead, est=est,
                truth=truth)
    return out


def run() -> list[CsvOut]:
    return [figure5(), figure6()]


if __name__ == "__main__":
    for t in run():
        t.emit()
