"""Table 4: network-aware vs vanilla shuffling across oversubscription ratios.

Execution speedup comes from the calibrated topology cost model (bytes are
measured exactly; time = modelled BSP completion, per DESIGN.md §2 — the
container cannot host two racks of servers).  The S/R/G decision string is read
from the adaptive template's recorded EFF/COST decisions.
"""
from __future__ import annotations

import numpy as np

from repro.apps.graph.engine import PregelEngine, rmat_graph
from repro.apps.graph.programs import PageRank, SSSP
from repro.core import TeShuService

from .common import CsvOut, paper_topology

# Graphs sized so wire time dominates the modelled completion (as in the
# paper's billion-edge runs) rather than per-epoch latency constants.
GRAPHS = {
    "UK": dict(num_vertices=16384, num_edges=500_000, seed=11,
               a=0.65, b=0.15, c=0.15),     # web-like: steeper skew
    "FR": dict(num_vertices=16384, num_edges=500_000, seed=13,
               a=0.57, b=0.19, c=0.19),     # social-like
}
RATIOS = (10.0, 4.0, 1.0)


def _decision_string(per_superstep) -> str:
    """Decision of the heaviest superstep (the paper reports the steady-state
    plan; SSSP's early tiny frontiers legitimately skip local combining)."""
    best = None
    for decs in per_superstep:
        if decs:
            letters = tuple(
                {"server": "S", "rack": "R"}.get(level, "?")
                for level, ec in decs if ec.beneficial)
            best = letters          # later supersteps carry the big frontier
    if best is None:
        return "G"
    return ",".join(best + ("G",))


def run_cell(workload: str, graph_name: str, ratio: float, *,
             supersteps: int = 4) -> dict:
    g = rmat_graph(**GRAPHS[graph_name])
    program = PageRank(supersteps) if workload == "PR" else SSSP(0, supersteps)

    results = {}
    for template in ("vanilla_push", "network_aware"):
        svc = TeShuService(paper_topology(ratio))
        engine = PregelEngine(g, svc, template_id=template, rate=0.01)
        engine.run(program)
        stats = svc.stats()
        results[template] = (stats, engine.decisions)

    v_stats, _ = results["vanilla_push"]
    a_stats, decisions = results["network_aware"]
    # communication saving counts bytes that crossed the top boundary
    v_global = v_stats["bytes_per_level"]["global"]
    a_global = a_stats["bytes_per_level"]["global"]
    saving = 1.0 - a_global / max(v_global, 1)
    speedup = v_stats["modelled_time_s"] / max(a_stats["modelled_time_s"], 1e-12)
    dec = _decision_string(decisions)
    return {"speedup": speedup, "saving": saving, "decision": dec}


def table4() -> CsvOut:
    out = CsvOut("table4_adaptive_shuffling",
                 ["oversubscription", "workload", "speedup", "comm_saving_pct",
                  "decision"])
    for ratio in RATIOS:
        for wl in ("PR", "SSSP"):
            for gname in GRAPHS:
                cell = run_cell(wl, gname, ratio)
                out.add(oversubscription=f"{ratio:g}:1",
                        workload=f"{wl}-{gname}",
                        speedup=cell["speedup"],
                        comm_saving_pct=100 * cell["saving"],
                        decision=cell["decision"])
    return out


def run() -> list[CsvOut]:
    return [table4()]


if __name__ == "__main__":
    for t in run():
        t.emit()
