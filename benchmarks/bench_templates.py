"""Table 3: template expressiveness — lines of TeShu template code per shuffle
algorithm, plus a byte/time profile of each template on a common workload, plus
the plan-cache / vectorization benchmark (beyond-paper: repeated shuffles)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (HASH_PART, SUM, TEMPLATES, Msgs, ShuffleArgs,
                        TeShuService, datacenter, fat_tree, multipod_dcn,
                        run_shuffle, template_loc)

from .common import CsvOut, paper_topology, zipf_shards


def table3() -> CsvOut:
    out = CsvOut("table3_template_loc",
                 ["algorithm", "pattern", "loc", "paper_loc"])
    paper = {"vanilla_push": 5, "coordinated": 9, "bruck": 11,
             "two_level": 18, "network_aware": 48}
    for tid, ref_loc in paper.items():
        t = TEMPLATES[tid]
        out.add(algorithm=tid, pattern=t.mode, loc=t.loc(), paper_loc=ref_loc)
    return out


def template_profile() -> CsvOut:
    """Same workload through every template: bytes per level + modelled time."""
    out = CsvOut("template_profile",
                 ["template", "total_mb", "global_mb", "modelled_ms"])
    # 16 workers (square, for two_level) across 2 racks so the global
    # boundary is actually exercised
    topo = datacenter(4, 2, 2, oversubscription=4.0)
    nw = topo.num_workers
    for tid in ("vanilla_push", "vanilla_pull", "coordinated", "bruck",
                "two_level", "network_aware"):
        svc = TeShuService(topo)
        bufs = zipf_shards(nw, 20_000, 20_000, seed=3)
        svc.shuffle(tid, bufs, list(range(nw)), list(range(nw)),
                    comb_fn=SUM, rate=0.01)
        st = svc.stats()
        out.add(template=tid, total_mb=st["total_bytes"] / 1e6,
                global_mb=st["bytes_per_level"].get("global", 0) / 1e6,
                modelled_ms=st["modelled_time_s"] * 1e3)
    return out


def plan_cache_profile(iters: int = 8) -> CsvOut:
    """Plan-cache hit/miss + vectorization speedup on repeated shuffles.

    Three executions of the *same* (template, topology, workload) key:

    * ``fresh``   — paper-faithful: re-instantiate every call (cache bypassed);
    * ``cached``  — plan-cache hit, thread-per-worker reference executor;
    * ``vector``  — plan-cache hit, batched-numpy data plane.

    ``setup`` speedup isolates the control-plane saving (instantiation skipped),
    ``vector`` speedup adds the data-plane win; ``samp_kb`` is the per-shuffle
    sampling traffic the cache eliminates (0 on every hit).  Outputs are asserted
    identical across all three paths before timing is reported.
    """
    out = CsvOut("plan_cache_profile",
                 ["topology", "template", "workers", "fresh_ms", "cached_ms",
                  "vector_ms", "setup_speedup", "vector_speedup", "samp_kb",
                  "hits"])
    topologies = {
        "paper_2rack": paper_topology(oversubscription=10.0),
        "fat_tree": fat_tree(2, 2, 2, 2, edge_oversubscription=4.0,
                             core_oversubscription=4.0),
        "multipod_dcn": multipod_dcn(4, 2, 2),
    }
    for topo_name, topo in topologies.items():
        nw = topo.num_workers
        base = zipf_shards(nw, 10_000, 5_000, seed=11)
        workers = list(range(nw))
        for tid in ("vanilla_push", "network_aware"):
            svc = TeShuService(topo)

            def copy_bufs():
                return {w: m.copy() for w, m in base.items()}

            def one_fresh():
                # paper-faithful baseline: the raw driver, no signature/compile/
                # cache work inside the timed region
                bufs = copy_bufs()
                args = ShuffleArgs(tid, svc.next_shuffle_id(), tuple(workers),
                                   tuple(workers), part_fn=HASH_PART,
                                   comb_fn=SUM, rate=0.01)
                t0 = time.perf_counter()
                res = run_shuffle(svc.cluster, args, bufs, manager=svc.manager)
                return time.perf_counter() - t0, res

            def one(execution: str):
                bufs = copy_bufs()
                t0 = time.perf_counter()
                res = svc.shuffle(tid, bufs, workers, workers, comb_fn=SUM,
                                  rate=0.01, execution=execution)
                return time.perf_counter() - t0, res

            _, ref = one("auto")                  # warm: compiles the plan
            svc.reset_stats()
            fresh = [one_fresh() for _ in range(iters)]
            samp_kb = svc.stats()["sample_bytes"] / len(fresh) / 1e3
            cached = [one("threaded") for _ in range(iters)]
            vector = [one("auto") for _ in range(iters)]
            for _, res in cached + vector:        # identical outputs, all paths
                for w in ref.bufs:
                    a, b = ref.bufs[w], res.bufs[w]
                    oa, ob = np.argsort(a.keys), np.argsort(b.keys)
                    assert np.array_equal(a.keys[oa], b.keys[ob])
                    assert np.array_equal(a.vals[oa], b.vals[ob])
            st = svc.cache_stats()
            f = float(np.median([t for t, _ in fresh]))
            c = float(np.median([t for t, _ in cached]))
            v = float(np.median([t for t, _ in vector]))
            out.add(topology=topo_name, template=tid, workers=nw,
                    fresh_ms=f * 1e3, cached_ms=c * 1e3, vector_ms=v * 1e3,
                    setup_speedup=f / max(c, 1e-12),
                    vector_speedup=f / max(v, 1e-12),
                    samp_kb=samp_kb, hits=st["hits"])
    return out


def run() -> list[CsvOut]:
    return [table3(), template_profile(), plan_cache_profile()]


if __name__ == "__main__":
    for t in run():
        t.emit()
