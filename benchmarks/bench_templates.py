"""Table 3: template expressiveness — lines of TeShu template code per shuffle
algorithm, plus a byte/time profile of each template on a common workload."""
from __future__ import annotations

from repro.core import SUM, TEMPLATES, TeShuService, datacenter, template_loc

from .common import CsvOut, paper_topology, zipf_shards


def table3() -> CsvOut:
    out = CsvOut("table3_template_loc",
                 ["algorithm", "pattern", "loc", "paper_loc"])
    paper = {"vanilla_push": 5, "coordinated": 9, "bruck": 11,
             "two_level": 18, "network_aware": 48}
    for tid, ref_loc in paper.items():
        t = TEMPLATES[tid]
        out.add(algorithm=tid, pattern=t.mode, loc=t.loc(), paper_loc=ref_loc)
    return out


def template_profile() -> CsvOut:
    """Same workload through every template: bytes per level + modelled time."""
    out = CsvOut("template_profile",
                 ["template", "total_mb", "global_mb", "modelled_ms"])
    # 16 workers (square, for two_level) across 2 racks so the global
    # boundary is actually exercised
    topo = datacenter(4, 2, 2, oversubscription=4.0)
    nw = topo.num_workers
    for tid in ("vanilla_push", "vanilla_pull", "coordinated", "bruck",
                "two_level", "network_aware"):
        svc = TeShuService(topo)
        bufs = zipf_shards(nw, 20_000, 20_000, seed=3)
        svc.shuffle(tid, bufs, list(range(nw)), list(range(nw)),
                    comb_fn=SUM, rate=0.01)
        st = svc.stats()
        out.add(template=tid, total_mb=st["total_bytes"] / 1e6,
                global_mb=st["bytes_per_level"].get("global", 0) / 1e6,
                modelled_ms=st["modelled_time_s"] * 1e3)
    return out


def run() -> list[CsvOut]:
    return [table3(), template_profile()]


if __name__ == "__main__":
    for t in run():
        t.emit()
