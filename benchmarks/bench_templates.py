"""Table 3: template expressiveness — lines of TeShu template code per shuffle
algorithm, plus a byte/time profile of each template on a common workload, plus
the plan-cache / vectorization benchmark (beyond-paper: repeated shuffles),
the skew-rebalance benchmark (``BENCH_skew.json``, machine-readable), the
streaming benchmark (``BENCH_streaming.json``: barrier vs chunk-pipelined
modelled time on both executors), the jitted-replay benchmark
(``BENCH_jaxplan.json``: fresh vs vectorized-hit vs jax-hit on all six
templates, plus serial-vs-batched multi-tenant dispatch) and the
durable-storage benchmark (``BENCH_storage.json``: off vs spill vs durable
overhead plus recovery-from-store vs naive re-execution)."""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import numpy as np

from repro.core import (HASH_PART, SUM, TEMPLATES, Msgs, ShuffleArgs,
                        TeShuCluster, TeShuService, datacenter,
                        dst_load_imbalance, fat_tree, multipod_dcn,
                        replay_cache_size, run_shuffle, template_loc)

from .common import CsvOut, paper_topology, zipf_shards


def table3() -> CsvOut:
    out = CsvOut("table3_template_loc",
                 ["algorithm", "pattern", "loc", "paper_loc"])
    paper = {"vanilla_push": 5, "coordinated": 9, "bruck": 11,
             "two_level": 18, "network_aware": 48}
    for tid, ref_loc in paper.items():
        t = TEMPLATES[tid]
        out.add(algorithm=tid, pattern=t.mode, loc=t.loc(), paper_loc=ref_loc)
    return out


def template_profile() -> CsvOut:
    """Same workload through every template: bytes per level + modelled time."""
    out = CsvOut("template_profile",
                 ["template", "total_mb", "global_mb", "modelled_ms"])
    # 16 workers (square, for two_level) across 2 racks so the global
    # boundary is actually exercised
    topo = datacenter(4, 2, 2, oversubscription=4.0)
    nw = topo.num_workers
    for tid in ("vanilla_push", "vanilla_pull", "coordinated", "bruck",
                "two_level", "network_aware"):
        svc = TeShuService(topo)
        bufs = zipf_shards(nw, 20_000, 20_000, seed=3)
        svc.shuffle(tid, bufs, list(range(nw)), list(range(nw)),
                    comb_fn=SUM, rate=0.01)
        st = svc.stats()
        out.add(template=tid, total_mb=st["total_bytes"] / 1e6,
                global_mb=st["bytes_per_level"].get("global", 0) / 1e6,
                modelled_ms=st["modelled_time_s"] * 1e3)
    return out


def plan_cache_profile(iters: int = 8) -> CsvOut:
    """Plan-cache hit/miss + vectorization speedup on repeated shuffles.

    Three executions of the *same* (template, topology, workload) key:

    * ``fresh``   — paper-faithful: re-instantiate every call (cache bypassed);
    * ``cached``  — plan-cache hit, thread-per-worker reference executor;
    * ``vector``  — plan-cache hit, batched-numpy data plane.

    ``setup`` speedup isolates the control-plane saving (instantiation skipped),
    ``vector`` speedup adds the data-plane win; ``samp_kb`` is the per-shuffle
    sampling traffic the cache eliminates (0 on every hit).  Outputs are asserted
    identical across all three paths before timing is reported.
    """
    out = CsvOut("plan_cache_profile",
                 ["topology", "template", "workers", "fresh_ms", "cached_ms",
                  "vector_ms", "setup_speedup", "vector_speedup", "samp_kb",
                  "hits"])
    topologies = {
        "paper_2rack": paper_topology(oversubscription=10.0),
        "fat_tree": fat_tree(2, 2, 2, 2, edge_oversubscription=4.0,
                             core_oversubscription=4.0),
        "multipod_dcn": multipod_dcn(4, 2, 2),
    }
    for topo_name, topo in topologies.items():
        nw = topo.num_workers
        base = zipf_shards(nw, 10_000, 5_000, seed=11)
        workers = list(range(nw))
        for tid in ("vanilla_push", "network_aware"):
            svc = TeShuService(topo)

            def copy_bufs():
                return {w: m.copy() for w, m in base.items()}

            def one_fresh():
                # paper-faithful baseline: the raw driver, no signature/compile/
                # cache work inside the timed region
                bufs = copy_bufs()
                args = ShuffleArgs(tid, svc.next_shuffle_id(), tuple(workers),
                                   tuple(workers), part_fn=HASH_PART,
                                   comb_fn=SUM, rate=0.01)
                t0 = time.perf_counter()
                res = run_shuffle(svc.cluster, args, bufs, manager=svc.manager)
                return time.perf_counter() - t0, res

            def one(execution: str):
                bufs = copy_bufs()
                t0 = time.perf_counter()
                res = svc.shuffle(tid, bufs, workers, workers, comb_fn=SUM,
                                  rate=0.01, execution=execution)
                return time.perf_counter() - t0, res

            _, ref = one("auto")                  # warm: compiles the plan
            svc.reset_stats()
            fresh = [one_fresh() for _ in range(iters)]
            samp_kb = svc.stats()["sample_bytes"] / len(fresh) / 1e3
            cached = [one("threaded") for _ in range(iters)]
            vector = [one("auto") for _ in range(iters)]
            for _, res in cached + vector:        # identical outputs, all paths
                for w in ref.bufs:
                    a, b = ref.bufs[w], res.bufs[w]
                    oa, ob = np.argsort(a.keys), np.argsort(b.keys)
                    assert np.array_equal(a.keys[oa], b.keys[ob])
                    assert np.array_equal(a.vals[oa], b.vals[ob])
            st = svc.cache_stats()
            f = float(np.median([t for t, _ in fresh]))
            c = float(np.median([t for t, _ in cached]))
            v = float(np.median([t for t, _ in vector]))
            out.add(topology=topo_name, template=tid, workers=nw,
                    fresh_ms=f * 1e3, cached_ms=c * 1e3, vector_ms=v * 1e3,
                    setup_speedup=f / max(c, 1e-12),
                    vector_speedup=f / max(v, 1e-12),
                    samp_kb=samp_kb, hits=st["hits"])
    return out


def skew_profile(iters: int = 4, *, smoke: bool = False,
                 json_path: str | None = None) -> CsvOut:
    """Skew rebalancing: uniform vs Zipf(1.2), balance off vs auto, both
    executors.  The perf-trajectory quantity is ``max_recv_mb`` — the bytes
    landing on the hottest destination, i.e. the tail the shuffle completes
    on — plus its max/mean imbalance and wall/modelled time.

    When ``json_path`` is set, the rows are also written as machine-readable
    JSON (``BENCH_skew.json``): ``{"meta": {...}, "rows": [...]}`` with one
    row per (workload, balance, executor), consumed by the CI smoke job.
    """
    out = CsvOut("skew_profile",
                 ["workload", "balance", "executor", "rebalanced", "splits",
                  "max_recv_mb", "mean_recv_mb", "imbalance", "modelled_ms",
                  "wall_ms", "cache_hits"])
    topo = datacenter(4, 2, 1)            # 8 workers across 2 servers
    nw = topo.num_workers
    workers = list(range(nw))
    n_per = 4_000 if smoke else 40_000
    loops = 2 if smoke else iters
    workloads = {
        "uniform": zipf_shards(nw, n_per, 20_000, alpha=0.0, seed=7),
        "zipf_1.2": zipf_shards(nw, n_per, 500, alpha=1.2, seed=7),
    }
    rows = []
    for wl_name, base in workloads.items():
        for balance in ("off", "auto"):
            for executor in ("threaded", "auto"):
                svc = TeShuService(topo, balance=balance, execution=executor)

                def one():
                    bufs = {w: m.copy() for w, m in base.items()}
                    t0 = time.perf_counter()
                    res = svc.shuffle("vanilla_push", bufs, workers, workers,
                                      comb_fn=SUM, rate=0.01)
                    return time.perf_counter() - t0, res

                one()                      # warm: compiles (and caches) the plan
                svc.reset_stats()
                runs = [one() for _ in range(loops)]
                _, last = runs[-1]
                st = svc.stats()
                recv = st["recv_bytes_per_worker"]
                loads = [recv.get(d, 0) / loops for d in workers]
                dec = dict(last.decisions).get("rebalance")
                row = dict(
                    workload=wl_name, balance=balance, executor=executor,
                    rebalanced=bool(dec is not None and dec.triggered),
                    splits=len(dec.splits) if dec is not None else 0,
                    max_recv_mb=max(loads) / 1e6,
                    mean_recv_mb=(sum(loads) / len(loads)) / 1e6,
                    imbalance=dst_load_imbalance(st, workers) or 1.0,
                    modelled_ms=st["modelled_time_s"] / loops * 1e3,
                    wall_ms=float(np.median([t for t, _ in runs])) * 1e3,
                    cache_hits=svc.cache_stats()["hits"])
                rows.append(row)
                out.add(**row)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"meta": {"bench": "skew_profile", "workers": nw,
                                "n_per_worker": n_per, "iters": loops,
                                "template": "vanilla_push", "smoke": smoke},
                       "rows": rows}, f, indent=2)
            f.write("\n")
    return out


def streaming_profile(iters: int = 3, *, smoke: bool = False,
                      json_path: str | None = None) -> CsvOut:
    """Barrier vs chunk-pipelined execution, both executors.

    Workload: every worker holds the same key pool permuted — no intra-worker
    dedup (the exchanges stay data-heavy) but heavy cross-worker duplication
    (hierarchical combining stays beneficial), i.e. the regime where both the
    multi-stage decisions *and* the transfer/combine overlap matter.  The
    perf-trajectory quantity is ``modelled_ms``: the pipeline bound
    ``max(X, C) + min(X, C)/n`` per streamed sub-epoch vs the BSP sum — plus
    the modelled speedup and wall time.  Outputs are asserted byte-identical
    between the two execution models before anything is reported.

    When ``json_path`` is set the rows are also written machine-readable
    (``BENCH_streaming.json``): one row per (template, executor, streaming),
    consumed by the CI smoke job, which gates on pipelined <= barrier for
    every streamable template and strictly below on the multi-stage one.
    """
    out = CsvOut("streaming_profile",
                 ["template", "executor", "streaming", "streamed", "chunks",
                  "modelled_ms", "speedup", "wall_ms", "total_mb"])
    topo = datacenter(4, 2, 2, oversubscription=8.0)
    nw = topo.num_workers
    workers = list(range(nw))
    # smoke stays data-dominated: each streamed sub-epoch pays one fixed
    # level latency, so the pipeline win needs per-stage data time >> 10us
    n_per = 15_000 if smoke else 30_000
    loops = 2 if smoke else iters
    chunk_bytes = 32 * 1024 if smoke else 64 * 1024
    rng = np.random.default_rng(3)
    pool = np.arange(n_per)
    base = {w: Msgs(rng.permutation(pool), rng.random((n_per, 1)))
            for w in workers}
    rows = []
    for tid in ("vanilla_push", "coordinated", "network_aware"):
        ref = None
        for executor in ("threaded", "auto"):
            for streaming in ("off", "auto"):
                svc = TeShuService(topo, execution=executor,
                                   streaming=streaming, chunk_bytes=chunk_bytes)

                def one():
                    bufs = {w: m.copy() for w, m in base.items()}
                    t0 = time.perf_counter()
                    res = svc.shuffle(tid, bufs, workers, workers,
                                      comb_fn=SUM, rate=0.02)
                    return time.perf_counter() - t0, res

                one()                      # warm: compiles (and caches) the plan
                svc.reset_stats()
                runs = [one() for _ in range(loops)]
                _, last = runs[-1]
                if ref is None:
                    ref = last.bufs
                else:                      # byte-identical across all modes
                    for d in ref:
                        a, b = ref[d], last.bufs[d]
                        assert np.array_equal(a.keys, b.keys)
                        assert np.array_equal(a.vals, b.vals)
                st = svc.stats()
                row = dict(
                    template=tid, executor=executor, streaming=streaming,
                    streamed=bool(last.streamed),
                    chunks=(last.stats["total_bytes"] // chunk_bytes),
                    modelled_ms=st["modelled_time_s"] / loops * 1e3,
                    speedup=1.0,
                    wall_ms=float(np.median([t for t, _ in runs])) * 1e3,
                    total_mb=st["total_bytes"] / loops / 1e6)
                rows.append(row)
        for executor in ("threaded", "auto"):
            bar = next(r for r in rows
                       if (r["template"], r["executor"],
                           r["streaming"]) == (tid, executor, "off"))
            pipe = next(r for r in rows
                        if (r["template"], r["executor"],
                            r["streaming"]) == (tid, executor, "auto"))
            pipe["speedup"] = bar["modelled_ms"] / max(pipe["modelled_ms"],
                                                       1e-12)
    for row in rows:
        out.add(**row)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"meta": {"bench": "streaming_profile", "workers": nw,
                                "n_per_worker": n_per, "iters": loops,
                                "chunk_bytes": chunk_bytes, "smoke": smoke},
                       "rows": rows}, f, indent=2)
            f.write("\n")
    return out


def multitenant_profile(*, smoke: bool = False,
                        json_path: str | None = None) -> CsvOut:
    """Cross-tenant admission scheduling: weighted-fair vs FIFO mean CCT.

    Concurrent tenants submit shuffles to one :class:`TeShuCluster` and
    ``run_pending()`` executes them in scheduled order.  Two workload mixes:

    * ``uniform``     — three tenants, equal-size uniform-keyed shuffles
      (scheduling cannot help: wfair must merely not hurt);
    * ``mixed_skew``  — a large uniform ETL tenant submits *first*, then a
      medium Zipf(1.2) tenant and a small high-priority ad-hoc tenant: the
      FIFO head-of-line-blocking regime, where weighted-fair ordering
      strictly cuts mean coflow completion time.

    The perf-trajectory quantity is ``mean_cct_ms`` — realized per-coflow
    completion times in ledger modelled seconds, averaged — per (mix,
    policy).  When ``json_path`` is set the rows are also written
    machine-readable (``BENCH_multitenant.json``), consumed by the CI smoke
    job, which gates on wfair <= FIFO for both mixes and strictly below on
    ``mixed_skew``.
    """
    out = CsvOut("multitenant_profile",
                 ["mix", "policy", "tenants", "coflows", "first_scheduled",
                  "mean_cct_ms", "makespan_ms", "wall_ms"])
    topo = datacenter(4, 2, 1)            # 8 workers across 2 servers
    nw = topo.num_workers
    workers = list(range(nw))
    scale = 1 if smoke else 4

    def submit_mix(cl: TeShuCluster, mix: str) -> None:
        if mix == "uniform":
            for i, name in enumerate(("t0", "t1", "t2")):
                t = cl.tenant(name)
                t.submit("vanilla_push",
                         zipf_shards(nw, 4_000 * scale, 20_000, alpha=0.0,
                                     seed=50 + i),
                         workers, workers, comb_fn=SUM, stage="s")
        else:                             # mixed_skew: big-first arrivals
            etl = cl.tenant("etl")
            ml = cl.tenant("ml")
            adhoc = cl.tenant("adhoc", priority=2.0)
            etl.submit("vanilla_push",
                       zipf_shards(nw, 20_000 * scale, 20_000, alpha=0.0,
                                   seed=60),
                       workers, workers, comb_fn=SUM, stage="stage-1")
            ml.submit("vanilla_push",
                      zipf_shards(nw, 5_000 * scale, 500, alpha=1.2, seed=61),
                      workers, workers, comb_fn=SUM, stage="step-9")
            adhoc.submit("vanilla_push",
                         zipf_shards(nw, 800 * scale, 2_000, alpha=0.0,
                                     seed=62),
                         workers, workers, comb_fn=SUM, stage="join-2")

    rows = []
    for mix in ("uniform", "mixed_skew"):
        for policy in ("fifo", "wfair"):
            cl = TeShuCluster(topo, admission=policy)
            submit_mix(cl, mix)
            t0 = time.perf_counter()
            cl.run_pending()
            wall = time.perf_counter() - t0
            sched = cl.last_schedule()
            row = dict(
                mix=mix, policy=policy, tenants=len(cl.tenants()),
                coflows=len(sched["ccts"]),
                first_scheduled=sched["planned"][0].coflow_id[0],
                mean_cct_ms=sched["mean_cct_s"] * 1e3,
                makespan_ms=sched["makespan_s"] * 1e3,
                wall_ms=wall * 1e3)
            rows.append(row)
            out.add(**row)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"meta": {"bench": "multitenant_profile", "workers": nw,
                                "scale": scale, "template": "vanilla_push",
                                "smoke": smoke},
                       "rows": rows}, f, indent=2)
            f.write("\n")
    return out


def jaxplan_profile(iters: int = 4, *, smoke: bool = False,
                    json_path: str | None = None) -> CsvOut:
    """Jitted plan replay: fresh vs vectorized-hit vs jax-hit, all six
    templates, plus batched multi-tenant dispatch.

    Three paths through the *same* (template, topology, workload) key:

    * ``fresh``          — paper-faithful re-instantiation every call;
    * ``vectorized_hit`` — plan-cache hit on the batched-numpy data plane
      (falls to ``threaded`` on the irregular bruck / two_level routes,
      which only the jitted plane lowers);
    * ``jax_hit``        — plan-cache hit lowered to one jitted program
      (``executor="jax"``) — every template, including bruck / two_level.

    Then two batched-dispatch rows on ``vanilla_push``:

    * ``serial_batch``   — four same-signature tenants replayed one by one;
    * ``batched``        — the same four submitted through the admission
      queue and executed as ONE vmapped dispatch by ``run_pending()``.

    Outputs are asserted byte-identical across paths before anything is
    reported, ``traces`` records jit-cache growth *during the timed loop*
    (must be 0: one trace per plan shape — and one per batch width — paid at
    warmup), and ``engine`` is what :class:`ShuffleResult` reports actually
    ran.  When ``json_path`` is set the rows are written machine-readable
    (``BENCH_jaxplan.json``), consumed by the CI smoke job, which gates on
    byte-identity, zero steady-state retraces, jax-hit modelled cost no
    worse than the vectorized hit on every template, and batched modelled
    cost strictly below the serial jax-hit pass.
    """
    out = CsvOut("jaxplan_profile",
                 ["template", "path", "engine", "identical", "traces",
                  "modelled_ms", "wall_ms", "total_mb", "cache_hits"])
    topo = datacenter(4, 2, 2, oversubscription=4.0)   # 16 = 4x4: square grid
    nw = topo.num_workers
    workers = list(range(nw))
    n_per = 2_000 if smoke else 20_000
    loops = 2 if smoke else iters
    rows = []
    for tid in ("vanilla_push", "vanilla_pull", "coordinated", "bruck",
                "two_level", "network_aware"):
        base = zipf_shards(nw, n_per, 5_000, alpha=0.0, seed=13)
        ref = None
        for path, kw in (
                ("fresh", dict(execution="fresh")),
                ("vectorized_hit", dict(executor="vectorized")),
                ("jax_hit", dict(executor="jax"))):
            svc = TeShuService(topo, **kw)

            def one():
                bufs = {w: m.copy() for w, m in base.items()}
                t0 = time.perf_counter()
                res = svc.shuffle(tid, bufs, workers, workers,
                                  comb_fn=SUM, rate=0.01)
                return time.perf_counter() - t0, res

            one()                # warm: compile + cache the plan (miss)
            one()                # warm: first hit pays the one jit trace
            traces_before = replay_cache_size()
            svc.reset_stats()
            runs = [one() for _ in range(loops)]
            _, last = runs[-1]
            identical = True
            if ref is None:
                ref = last.bufs
            else:                # byte-identical across all three paths
                for d in ref:
                    a, b = ref[d], last.bufs[d]
                    oa, ob = np.argsort(a.keys), np.argsort(b.keys)
                    identical = (identical
                                 and np.array_equal(a.keys[oa], b.keys[ob])
                                 and np.array_equal(a.vals[oa], b.vals[ob]))
                assert identical, f"{tid}/{path}: output diverged"
            st = svc.stats()
            row = dict(
                template=tid, path=path, engine=last.engine,
                identical=identical,
                traces=replay_cache_size() - traces_before,
                modelled_ms=st["modelled_time_s"] / loops * 1e3,
                wall_ms=float(np.median([t for t, _ in runs])) * 1e3,
                total_mb=st["total_bytes"] / loops / 1e6,
                cache_hits=svc.cache_stats()["hits"])
            rows.append(row)
            out.add(**row)

    # ---- batched multi-tenant dispatch: 4 same-signature wfair tenants ----
    base = zipf_shards(nw, n_per, 5_000, alpha=0.0, seed=13)
    cl = TeShuCluster(topo, execution="auto", executor="jax")
    tenants = [cl.tenant(f"t{i}") for i in range(4)]

    def batch_pass(batched):
        t0 = time.perf_counter()
        if batched:
            tickets = [t.submit("vanilla_push",
                                {w: m.copy() for w, m in base.items()},
                                workers, workers, comb_fn=SUM, rate=0.01)
                       for t in tenants]
            res = cl.run_pending()
            outs = [res[tk] for tk in tickets]
        else:
            outs = [t.shuffle("vanilla_push",
                              {w: m.copy() for w, m in base.items()},
                              workers, workers, comb_fn=SUM, rate=0.01)
                    for t in tenants]
        return time.perf_counter() - t0, outs

    for t in tenants:
        batch_pass(False)        # warm: plan (miss) + the one solo jit trace
    batch_pass(True)             # warm: the one stacked (vmapped) trace
    serial_out = None
    for path, batched in (("serial_batch", False), ("batched", True)):
        traces_before = replay_cache_size()
        m0 = cl.cluster.ledger.snapshot()
        runs = [batch_pass(batched) for _ in range(loops)]
        m1 = cl.cluster.ledger.snapshot()
        outs = runs[-1][1]
        engines = {r.engine for r in outs}
        assert engines == {"jax"}, (path, engines)
        assert batched == all(r.batched for r in outs), path
        identical = True
        if serial_out is None:
            serial_out = [r.bufs for r in outs]
        else:                    # batched output == serial, physical order
            for ref_b, r in zip(serial_out, outs):
                for d in ref_b:
                    identical = (identical
                                 and np.array_equal(ref_b[d].keys,
                                                    r.bufs[d].keys)
                                 and np.array_equal(ref_b[d].vals,
                                                    r.bufs[d].vals))
            assert identical, "batched dispatch diverged from serial"
        row = dict(
            template="vanilla_push", path=path, engine="jax",
            identical=identical,
            traces=replay_cache_size() - traces_before,
            modelled_ms=(m1["modelled_time_s"] - m0["modelled_time_s"])
            / loops * 1e3,
            wall_ms=float(np.median([t for t, _ in runs])) * 1e3,
            total_mb=(m1["total_bytes"] - m0["total_bytes"]) / loops / 1e6,
            cache_hits=sum(t.cache_stats()["hits"] for t in tenants))
        rows.append(row)
        out.add(**row)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"meta": {"bench": "jaxplan_profile", "workers": nw,
                                "n_per_worker": n_per, "iters": loops,
                                "smoke": smoke},
                       "rows": rows}, f, indent=2)
            f.write("\n")
    return out


def observability_profile(iters: int = 4, *, smoke: bool = False,
                          json_path: str | None = None) -> CsvOut:
    """Telemetry-plane overhead: tracing off vs on, both replay executors.

    The same (template, topology, workload) key runs as plan-cache hits with
    the flight recorder disabled (the no-op tracer singleton) and enabled,
    for ``executor="vectorized"`` and ``executor="jax"``.  The contract the
    CI smoke job gates on:

    * tracing-off runs record **zero** spans (the disabled path allocates no
      span objects and reads no clocks);
    * modelled time is identical with tracing on and off (telemetry must
      never perturb the cost model);
    * the tracing-on wall-time overhead is <= 5% of the *modelled* per-run
      cost — the paper-scale quantity a shuffle is budgeted by — for both
      executors.

    When ``json_path`` is set the rows are written machine-readable
    (``BENCH_obs.json``): one row per (executor, tracing).
    """
    out = CsvOut("observability_profile",
                 ["executor", "tracing", "engine", "spans_per_run",
                  "modelled_ms", "wall_ms", "overhead_ms", "overhead_frac"])
    # a paper-testbed-like *slow* fabric: modelled cost is pure arithmetic, so
    # low bandwidths give a realistic multi-ms per-shuffle budget to gate the
    # (wall-clock) telemetry overhead against, without inflating wall time
    topo = datacenter(4, 2, 2, intra_server_bw=3.125e7, intra_rack_bw=3.125e6,
                      oversubscription=8.0, combine_bytes_per_s=3.125e7)
    nw = topo.num_workers
    workers = list(range(nw))
    n_per = 8_000 if smoke else 20_000
    loops = 9 if smoke else max(iters, 9)
    base = zipf_shards(nw, n_per, 5_000, alpha=0.0, seed=17)
    rows = []
    for executor in ("vectorized", "jax"):
        # ONE service per executor: tracing is toggled on the warmed instance,
        # so both measurements share the same plan cache and jit traces and
        # the off/on delta is the telemetry plane alone, not instance noise
        svc = TeShuService(topo, executor=executor)

        def one():
            bufs = {w: m.copy() for w, m in base.items()}
            t0 = time.perf_counter()
            res = svc.shuffle("vanilla_push", bufs, workers, workers,
                              comb_fn=SUM, rate=0.01)
            return time.perf_counter() - t0, res

        one()                    # warm: compile + cache the plan (miss)
        one()                    # warm: first hit (pays the jit trace on jax)
        # interleave off/on runs (toggling the tracer between runs) so
        # thermal/GC drift lands on both arms equally; best-of filters the
        # rest — the off/on delta is the telemetry plane alone
        runs: dict[bool, list] = {False: [], True: []}
        spans: dict[bool, int] = {False: 0, True: 0}
        modelled: dict[bool, float] = {}
        for tracing in (False, True):
            svc.enable_tracing() if tracing else svc.disable_tracing()
            svc.reset_stats()
            spans_before = len(svc.spans())
            runs[tracing].append(one())
            spans[tracing] = len(svc.spans()) - spans_before
            modelled[tracing] = svc.stats()["modelled_time_s"]
        for _ in range(loops - 1):
            for tracing in (False, True):
                svc.enable_tracing() if tracing else svc.disable_tracing()
                runs[tracing].append(one())
        walls = {tr: float(min(t for t, _ in rr)) for tr, rr in runs.items()}
        for tracing in (False, True):
            _, last = runs[tracing][-1]
            rows.append(dict(
                executor=executor, tracing=tracing, engine=last.engine,
                spans_per_run=spans[tracing],
                modelled_ms=modelled[tracing] * 1e3,
                wall_ms=walls[tracing] * 1e3,
                overhead_ms=0.0, overhead_frac=0.0))
        on = rows[-1]
        overhead_s = max(0.0, walls[True] - walls[False])
        on["overhead_ms"] = overhead_s * 1e3
        on["overhead_frac"] = overhead_s * 1e3 / max(on["modelled_ms"], 1e-12)
    for row in rows:
        out.add(**row)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"meta": {"bench": "observability_profile", "workers": nw,
                                "n_per_worker": n_per, "iters": loops,
                                "template": "vanilla_push", "smoke": smoke},
                       "rows": rows}, f, indent=2)
            f.write("\n")
    return out


def storage_profile(iters: int = 3, *, smoke: bool = False,
                    json_path: str | None = None) -> CsvOut:
    """Durable-storage cost/benefit: off vs spill vs durable.

    Three arms on a disjoint senders->receivers ``vanilla_push``:

    * ``overhead`` — no faults: what each storage mode costs.  Modelled time
      must be *identical* across modes (spill/restore live on their own
      ledger lanes, never on transfer time); wall time shows the real
      serialization/flush cost.
    * ``recovery`` — a sender killed mid-stage under ``resilience="recover"``:
      ``storage="off"`` re-executes every sender on the retry,
      ``storage="durable"`` serves the survivors' persisted PART outputs from
      the store.  The served arm must model **strictly less** total time than
      naive re-execution, at byte-identical output.
    * ``stream`` — a session fed past its inflight window: ``storage="spill"``
      spills the oldest chunks instead of folding early; folds must be
      bitwise-identical to the storage-off session.

    When ``json_path`` is set the rows are written machine-readable
    (``BENCH_storage.json``), consumed by the CI ``storage-bench-smoke`` job.
    """
    out = CsvOut("storage_profile",
                 ["arm", "storage", "modelled_ms", "wall_ms", "spill_mb",
                  "restore_mb", "served", "reexecuted", "spilled_chunks",
                  "identical"])
    topo = datacenter(4, 2, 2, oversubscription=4.0)
    nw = topo.num_workers
    srcs = list(range(nw // 2))
    dsts = list(range(nw // 2, nw))
    n_per = 4_000 if smoke else 20_000
    loops = 2 if smoke else max(iters, 2)
    # The recovery victim (srcs[-1]) carries a small shard and the survivors
    # carry large ones: modelled epoch time is a max over parallel senders,
    # so serving the survivors from the store must drop it strictly (the
    # naive retry stays bottlenecked on a large surviving shard).
    big = zipf_shards(len(srcs), n_per, 5_000, seed=11)
    small = zipf_shards(len(srcs), max(n_per // 10, 100), 5_000, seed=12)
    base = {w: (small[w] if w == srcs[-1] else big[w]) for w in srcs}

    def same(a, b):
        return set(a) == set(b) and all(
            np.array_equal(a[d].keys, b[d].keys)
            and np.array_equal(a[d].vals, b[d].vals) for d in a)

    def run_barrier(storage, *, fault):
        sv = TeShuService(topo, resilience="recover", storage=storage)
        sv.shuffle("vanilla_push", {w: m.copy() for w, m in base.items()},
                   srcs, dsts, comb_fn=SUM)       # prime the plan (excluded)
        best = None
        for _ in range(loops):
            if fault:
                sv.inject_fault(srcs[-1], after_stage=-1)
            sv.reset_stats()
            bufs = {w: m.copy() for w, m in base.items()}
            t0 = time.perf_counter()
            res = sv.shuffle("vanilla_push", bufs, srcs, dsts, comb_fn=SUM)
            wall = time.perf_counter() - t0
            st = sv.stats()
            if best is None or wall < best[0]:
                best = (wall, res, st)
        return best

    def run_stream(storage):
        best = None
        for _ in range(loops):
            cl = TeShuCluster(topo, storage=storage)
            sess = cl.tenant("bench").open_stream(
                "vanilla_push", srcs, dsts, comb_fn=SUM,
                chunk_bytes=1 << 14, max_inflight=2)
            t0 = time.perf_counter()
            for w, m in base.items():
                sess.feed({w: m.copy()})
            r = sess.drain()
            wall = time.perf_counter() - t0
            if best is None or wall < best[0]:
                best = (wall, r)
        return best

    rows = []
    wall0, res0, st0 = run_barrier("off", fault=False)
    ref = res0.bufs
    for storage in ("off", "spill", "durable"):
        wall, res, st = ((wall0, res0, st0) if storage == "off"
                         else run_barrier(storage, fault=False))
        rows.append(dict(
            arm="overhead", storage=storage,
            modelled_ms=st["modelled_time_s"] * 1e3, wall_ms=wall * 1e3,
            spill_mb=st.get("spill_bytes", 0) / 1e6,
            restore_mb=st.get("restore_bytes", 0) / 1e6,
            served=0, reexecuted=0, spilled_chunks=0,
            identical=same(res.bufs, ref)))
    for storage in ("off", "durable"):
        wall, res, st = run_barrier(storage, fault=True)
        served = len((res.recovery or {}).get("store_served", []))
        rows.append(dict(
            arm="recovery", storage=storage,
            modelled_ms=st["modelled_time_s"] * 1e3, wall_ms=wall * 1e3,
            spill_mb=st.get("spill_bytes", 0) / 1e6,
            restore_mb=st.get("restore_bytes", 0) / 1e6,
            served=served, reexecuted=len(srcs) - served,
            spilled_chunks=0, identical=same(res.bufs, ref)))
    wall0, r0 = run_stream("off")
    for storage in ("off", "spill"):
        wall, r = (wall0, r0) if storage == "off" else run_stream(storage)
        rows.append(dict(
            arm="stream", storage=storage,
            modelled_ms=r["stats"]["modelled_time_s"] * 1e3,
            wall_ms=wall * 1e3,
            spill_mb=r["stats"].get("spill_bytes", 0) / 1e6,
            restore_mb=r["stats"].get("restore_bytes", 0) / 1e6,
            served=0, reexecuted=0, spilled_chunks=r.get("spilled", 0),
            identical=same(r["bufs"], r0["bufs"])))
    for row in rows:
        out.add(**row)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"meta": {"bench": "storage_profile", "workers": nw,
                                "n_per_worker": n_per, "iters": loops,
                                "template": "vanilla_push", "smoke": smoke},
                       "rows": rows}, f, indent=2)
            f.write("\n")
    return out


def elastic_profile(*, smoke: bool = False,
                    json_path: str | None = None) -> CsvOut:
    """Fixed vs elastic topology under a bursty multi-tenant backlog.

    Two traces through three cluster shapes, on both replay executors:

    * ``bursty``  — nine coflows across three tenants submitted at once: the
      backlog regime where the :class:`BacklogPolicy` grows one burst rack
      at the first coflow boundary and re-targets every queued coflow onto
      the widened worker set;
    * ``uniform`` — two coflows, below the backlog threshold: the policy
      must hold (zero scale events) and the elastic cluster must behave
      exactly like the fixed one.

    Modes: ``fixed`` (the 8-worker base cluster), ``elastic`` (same base,
    ``elastic="auto"`` capped at 12 workers), and ``fixed_grown`` (a cluster
    *born* at 12 workers running the widened trace — the byte-identity
    reference for the elastic run).  ``digest`` hashes every coflow's
    per-destination output buffers in physical row order, so the CI gate can
    assert the elastic run's bytes match the born-grown reference on the
    bursty trace and the fixed base on the uniform trace.  When ``json_path``
    is set the rows are written machine-readable (``BENCH_elastic.json``),
    consumed by the ``elastic-bench-smoke`` CI job, which gates on elastic
    makespan strictly below fixed under backlog, byte identity, and zero
    scale events on the uniform trace.
    """
    out = CsvOut("elastic_profile",
                 ["trace", "mode", "executor", "coflows", "scale_events",
                  "workers_final", "makespan_ms", "mean_cct_ms", "digest",
                  "wall_ms"])
    # a combine-bound fabric: fat non-oversubscribed pipes, slow combiner.
    # Scale-out pays when the tail is per-receiver work, not sender wire
    # time -- burst receivers split the combine load, so the makespan win
    # is a property of the regime, not of a lucky workload size.
    fabric = dict(intra_server_bw=50e9, intra_rack_bw=50e9,
                  oversubscription=1.0, combine_bytes_per_s=2e8)
    base = datacenter(2, 2, 2, **fabric)                   # 8 workers
    grown = datacenter(2, 2, 3, **fabric)                  # born at 12
    nw = base.num_workers
    scale = 1 if smoke else 4
    n_per = 2_000 * scale

    def submit_trace(cl: TeShuCluster, trace: str) -> list[int]:
        # sources always live on the 8 base workers; destinations are "all
        # workers" of whatever size the receiving cluster was born at (the
        # elastic coordinator re-targets its own at the scale-out boundary)
        dsts = list(range(cl.topology.num_workers))
        tickets = []
        for i in range(9 if trace == "bursty" else 2):
            t = cl.tenant(("etl", "ml", "adhoc")[i % 3])
            tickets.append(t.submit(
                "vanilla_push",
                zipf_shards(nw, n_per, 4_096, alpha=0.0, seed=80 + i),
                list(range(nw)), dsts, comb_fn=SUM, stage=f"s{i}"))
        return tickets

    def digest(results: dict, tickets: list[int]) -> str:
        h = hashlib.sha256()
        for i, tk in enumerate(tickets):
            res = results[tk]
            if isinstance(res, Exception):
                raise res
            for d in sorted(res.bufs):
                m = res.bufs[d]
                h.update(np.int64(i).tobytes())
                h.update(np.int64(d).tobytes())
                h.update(np.ascontiguousarray(m.keys).tobytes())
                h.update(np.ascontiguousarray(m.vals).tobytes())
        return h.hexdigest()[:16]

    rows = []
    for executor in ("vectorized", "jax"):
        for trace in ("bursty", "uniform"):
            arms = [
                ("fixed", TeShuCluster(base, execution="auto",
                                       executor=executor)),
                ("elastic", TeShuCluster(base, execution="auto",
                                         executor=executor, elastic="auto",
                                         elastic_level="rack",
                                         elastic_backlog=4,
                                         elastic_max_workers=grown.num_workers)),
                ("fixed_grown", TeShuCluster(grown, execution="auto",
                                             executor=executor)),
            ]
            for mode, cl in arms:
                tickets = submit_trace(cl, trace)
                t0 = time.perf_counter()
                results = cl.run_pending(policy="fifo")
                wall = time.perf_counter() - t0
                sched = cl.last_schedule()
                row = dict(
                    trace=trace, mode=mode, executor=executor,
                    coflows=len(sched["ccts"]),
                    scale_events=len([e for e
                                      in sched.get("scale_events", ())
                                      if e["kind"] != "deny"]),
                    workers_final=cl.topology.num_workers,
                    makespan_ms=sched["makespan_s"] * 1e3,
                    mean_cct_ms=sched["mean_cct_s"] * 1e3,
                    digest=digest(results, tickets),
                    wall_ms=wall * 1e3)
                rows.append(row)
                out.add(**row)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"meta": {"bench": "elastic_profile", "workers": nw,
                                "grown_workers": grown.num_workers,
                                "n_per_worker": n_per,
                                "template": "vanilla_push", "smoke": smoke},
                       "rows": rows}, f, indent=2)
            f.write("\n")
    return out


def run() -> list[CsvOut]:
    return [table3(), template_profile(), plan_cache_profile(),
            skew_profile(json_path="BENCH_skew.json"),
            streaming_profile(json_path="BENCH_streaming.json"),
            multitenant_profile(json_path="BENCH_multitenant.json"),
            jaxplan_profile(json_path="BENCH_jaxplan.json"),
            observability_profile(json_path="BENCH_obs.json"),
            storage_profile(json_path="BENCH_storage.json"),
            elastic_profile(json_path="BENCH_elastic.json")]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--skew-only", action="store_true",
                    help="run only the skew benchmark")
    ap.add_argument("--streaming-only", action="store_true",
                    help="run only the streaming benchmark")
    ap.add_argument("--multitenant-only", action="store_true",
                    help="run only the multi-tenant scheduling benchmark")
    ap.add_argument("--jaxplan-only", action="store_true",
                    help="run only the jitted plan-replay benchmark")
    ap.add_argument("--obs-only", action="store_true",
                    help="run only the telemetry-overhead benchmark")
    ap.add_argument("--storage-only", action="store_true",
                    help="run only the durable-storage benchmark")
    ap.add_argument("--elastic-only", action="store_true",
                    help="run only the elastic-topology benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="small-scale run (CI)")
    ap.add_argument("--skew-json", default="BENCH_skew.json",
                    help="path for the machine-readable skew output")
    ap.add_argument("--streaming-json", default="BENCH_streaming.json",
                    help="path for the machine-readable streaming output")
    ap.add_argument("--multitenant-json", default="BENCH_multitenant.json",
                    help="path for the machine-readable multitenant output")
    ap.add_argument("--jaxplan-json", default="BENCH_jaxplan.json",
                    help="path for the machine-readable jaxplan output")
    ap.add_argument("--obs-json", default="BENCH_obs.json",
                    help="path for the machine-readable telemetry output")
    ap.add_argument("--storage-json", default="BENCH_storage.json",
                    help="path for the machine-readable storage output")
    ap.add_argument("--elastic-json", default="BENCH_elastic.json",
                    help="path for the machine-readable elastic output")
    args = ap.parse_args()
    if args.skew_only:
        skew_profile(smoke=args.smoke, json_path=args.skew_json).emit()
    elif args.streaming_only:
        streaming_profile(smoke=args.smoke,
                          json_path=args.streaming_json).emit()
    elif args.multitenant_only:
        multitenant_profile(smoke=args.smoke,
                            json_path=args.multitenant_json).emit()
    elif args.jaxplan_only:
        jaxplan_profile(smoke=args.smoke,
                        json_path=args.jaxplan_json).emit()
    elif args.obs_only:
        observability_profile(smoke=args.smoke,
                              json_path=args.obs_json).emit()
    elif args.storage_only:
        storage_profile(smoke=args.smoke,
                        json_path=args.storage_json).emit()
    elif args.elastic_only:
        elastic_profile(smoke=args.smoke,
                        json_path=args.elastic_json).emit()
    else:
        for t in run():
            t.emit()
