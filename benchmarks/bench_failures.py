"""§5.2/§6 robustness: link failures, worker death, and the recovery path.

Two suites:

* :func:`run_scenarios` — the paper's §5.2 experiment: random ToR↔spine link
  failures degrade spine bandwidth; the adaptive template re-decides per
  scenario.  Services share one PlanCache with resilience on, so every
  degraded scenario *repairs* the healthy-topology plan instead of
  re-instantiating (the `repairs`/`hits` columns show the control-plane work
  saved across the sweep).
* :func:`run_recovery` — beyond bandwidth arithmetic: a worker is actually
  killed mid-stage (`fail worker 3 after stage 0`) and the resilience layer
  recovers via participant-scoped restart from per-stage checkpoints, on both
  executors.  Reported against the no-failure run and against the naive
  alternative (abort + full re-execution), in wall-clock and in journal terms
  (how many workers re-executed the failed stage).
"""
from __future__ import annotations

import time

import numpy as np

from repro.apps.graph.engine import PregelEngine, rmat_graph
from repro.apps.graph.programs import PageRank
from repro.core import (SUM, Msgs, PlanCache, TeShuService, datacenter,
                        degrade_links)

from .common import CsvOut, paper_topology


def run_scenarios(n_scenarios: int = 20, fail_links: int = 3,
                  total_uplinks: int = 8) -> CsvOut:
    out = CsvOut("failure_robustness",
                 ["scenario_group", "vanilla_ms", "aware_ms", "speedup",
                  "plan_repairs", "plan_hits"])
    g = rmat_graph(8192, 200_000, seed=21)
    rng = np.random.default_rng(42)

    base = paper_topology(4.0)
    cache = PlanCache(capacity=1024)
    # warm the healthy-topology plans: every degraded scenario repairs these
    warm = TeShuService(base, plan_cache=cache, resilience="recover")
    PregelEngine(g, warm, template_id="network_aware", rate=0.01).run(PageRank(3))
    nofail = warm.stats()["modelled_time_s"]

    rows = []
    for s in range(n_scenarios):
        # each failed uplink removes 1/total_uplinks of spine capacity
        frac = min(0.9, fail_links * rng.uniform(0.5, 1.5) / total_uplinks)
        topo = degrade_links(base, "global", frac)
        times = {}
        for template in ("vanilla_push", "network_aware"):
            svc = TeShuService(topo, plan_cache=cache, resilience="recover")
            eng = PregelEngine(g, svc, template_id=template, rate=0.01)
            eng.run(PageRank(3))
            times[template] = svc.stats()["modelled_time_s"]
        rows.append((times["vanilla_push"], times["network_aware"]))

    v = np.asarray([r[0] for r in rows])
    a = np.asarray([r[1] for r in rows])
    st = cache.stats()
    out.add(scenario_group="failed_mean", vanilla_ms=float(v.mean() * 1e3),
            aware_ms=float(a.mean() * 1e3), speedup=float((v / a).mean()),
            plan_repairs=st["repairs"], plan_hits=st["hits"])
    out.add(scenario_group="failed_p95", vanilla_ms=float(np.percentile(v, 95) * 1e3),
            aware_ms=float(np.percentile(a, 95) * 1e3),
            speedup=float(np.percentile(v / a, 95)),
            plan_repairs=st["repairs"], plan_hits=st["hits"])
    out.add(scenario_group="no_failure_aware", vanilla_ms=0.0,
            aware_ms=float(nofail * 1e3), speedup=0.0,
            plan_repairs=0, plan_hits=0)
    return out


def _dup_heavy(nw: int, n: int = 4000, blocks: int = 100,
               key_space: int = 4096, seed: int = 3) -> dict[int, Msgs]:
    rng = np.random.default_rng(seed)
    base = rng.integers(0, key_space, blocks)
    out = {}
    for w in range(nw):
        keys = np.repeat(rng.permutation(base), n // blocks)
        out[w] = Msgs(keys, rng.random((keys.size, 1)))
    return out


def run_recovery(repeats: int = 3) -> CsvOut:
    """Kill worker 3 after the server stage; compare completion strategies.

    ``recovered_ms`` uses the resilience pipeline (checkpoints + journal
    replay, minimal restart); ``full_restart_ms`` is the naive strategy —
    abort, heal, re-run everything; ``no_failure_ms`` is the clean reference.
    ``restarted_workers`` counts journal `stage` records from the recovery
    attempt (threaded: the dead worker's rack group; vectorized lockstep: all
    senders, since nobody had entered the failed stage).
    """
    out = CsvOut("worker_failure_recovery",
                 ["executor", "no_failure_ms", "recovered_ms",
                  "full_restart_ms", "restarted_workers", "recovered_bytes_x"])
    topo = datacenter(2, 2, 2, oversubscription=10.0, combine_bytes_per_s=64e9)
    nw = topo.num_workers
    workers = list(range(nw))
    bufs = _dup_heavy(nw)

    def copy():
        return {w: m.copy() for w, m in bufs.items()}

    for executor in ("threaded", "auto"):
        svc = TeShuService(topo, execution=executor, resilience="recover")
        svc.shuffle("network_aware", copy(), workers, workers,
                    comb_fn=SUM, rate=0.05)                 # compile the plan

        def timed(fault: bool, recover: bool) -> tuple[float, int, int]:
            best, restarted, nbytes = float("inf"), 0, 0
            for _ in range(repeats):
                if fault:
                    svc.inject_fault(3, after_stage=0)
                sid = svc.next_shuffle_id()
                before = svc.stats()["total_bytes"]
                t0 = time.perf_counter()
                if recover or not fault:
                    res = svc.shuffle("network_aware", copy(), workers, workers,
                                      comb_fn=SUM, rate=0.05, shuffle_id=sid)
                    n = len({r.wid for r in
                             svc.manager.stage_records(sid, attempt=1)})
                else:
                    try:                                    # naive: fail, then
                        svc.shuffle("network_aware", copy(), workers, workers,
                                    comb_fn=SUM, rate=0.05, shuffle_id=sid,
                                    resilience="off")
                    except TimeoutError:
                        svc.restart_worker(3)
                    res = svc.shuffle("network_aware", copy(), workers, workers,
                                      comb_fn=SUM, rate=0.05)
                    n = len(workers)
                assert res.bufs
                dt = time.perf_counter() - t0
                if dt < best:
                    best, restarted = dt, n
                    nbytes = svc.stats()["total_bytes"] - before
            return best, restarted, nbytes

        clean, _, clean_bytes = timed(fault=False, recover=False)
        rec, restarted, rec_bytes = timed(fault=True, recover=True)
        naive, _, _ = timed(fault=True, recover=False)
        out.add(executor=executor, no_failure_ms=clean * 1e3,
                recovered_ms=rec * 1e3, full_restart_ms=naive * 1e3,
                restarted_workers=restarted,
                recovered_bytes_x=rec_bytes / max(1, clean_bytes))
    return out


def run() -> list[CsvOut]:
    return [run_scenarios(), run_recovery()]


if __name__ == "__main__":
    for t in run():
        t.emit()
