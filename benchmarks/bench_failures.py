"""§5.2 robustness: random link failures between ToR and spine.

The paper injects 3 random link failures per scenario over 100 scenarios and
reports that network-aware shuffling keeps completion times close to the
no-failure case (5x–8.2x faster than vanilla under failure).  Here a failure
degrades the affected boundary's effective bandwidth (surviving links carry the
load); the adaptive template re-decides per scenario.
"""
from __future__ import annotations

import numpy as np

from repro.apps.graph.engine import PregelEngine, rmat_graph
from repro.apps.graph.programs import PageRank
from repro.core import TeShuService, degrade_links

from .common import CsvOut, paper_topology


def run_scenarios(n_scenarios: int = 20, fail_links: int = 3,
                  total_uplinks: int = 8) -> CsvOut:
    out = CsvOut("failure_robustness",
                 ["scenario_group", "vanilla_ms", "aware_ms", "speedup"])
    g = rmat_graph(8192, 200_000, seed=21)
    rng = np.random.default_rng(42)

    base = paper_topology(4.0)
    rows = []
    for s in range(n_scenarios):
        # each failed uplink removes 1/total_uplinks of spine capacity
        frac = min(0.9, fail_links * rng.uniform(0.5, 1.5) / total_uplinks)
        topo = degrade_links(base, "global", frac)
        times = {}
        for template in ("vanilla_push", "network_aware"):
            svc = TeShuService(topo)
            eng = PregelEngine(g, svc, template_id=template, rate=0.01)
            eng.run(PageRank(3))
            times[template] = svc.stats()["modelled_time_s"]
        rows.append((times["vanilla_push"], times["network_aware"]))

    v = np.asarray([r[0] for r in rows])
    a = np.asarray([r[1] for r in rows])
    # no-failure reference
    svc = TeShuService(base)
    PregelEngine(g, svc, template_id="network_aware", rate=0.01).run(PageRank(3))
    nofail = svc.stats()["modelled_time_s"]

    out.add(scenario_group="failed_mean", vanilla_ms=float(v.mean() * 1e3),
            aware_ms=float(a.mean() * 1e3), speedup=float((v / a).mean()))
    out.add(scenario_group="failed_p95", vanilla_ms=float(np.percentile(v, 95) * 1e3),
            aware_ms=float(np.percentile(a, 95) * 1e3),
            speedup=float(np.percentile(v / a, 95)))
    out.add(scenario_group="no_failure_aware", vanilla_ms=0.0,
            aware_ms=float(nofail * 1e3), speedup=0.0)
    return out


def run() -> list[CsvOut]:
    return [run_scenarios()]


if __name__ == "__main__":
    for t in run():
        t.emit()
