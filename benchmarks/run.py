"""Benchmark aggregator: one suite per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Suites: sampling (Fig 5/6), templates (Table 3), adaptive (Table 4),
failures (§5.2), moe_shuffle (beyond-paper LM integration).

NOTE: moe_shuffle needs >=8 local devices; when run in the default single-
device container it reports 'skipped' rows (run with
XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise it; the test
suite does this in-process where safe).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single suite by name")
    args = ap.parse_args()

    from . import (bench_adaptive, bench_failures, bench_moe_shuffle,
                   bench_sampling, bench_templates)
    suites = {
        "templates": bench_templates.run,
        "sampling": bench_sampling.run,
        "adaptive": bench_adaptive.run,
        "failures": bench_failures.run,
        "moe_shuffle": bench_moe_shuffle.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    t00 = time.time()
    for name, fn in suites.items():
        t0 = time.time()
        print(f"\n##### suite: {name}", flush=True)
        try:
            for table in fn():
                table.emit()
        except Exception as e:                      # pragma: no cover
            print(f"suite {name} FAILED: {e}", file=sys.stderr)
            raise
        print(f"# suite {name} took {time.time()-t0:.1f}s", flush=True)
    print(f"\n# all suites done in {time.time()-t00:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
