"""Co-scheduling shuffles across tenants (paper §6, implemented).

Three tenants (a Spark-like job, a Pregel job, an ad-hoc query) submit
shuffles concurrently; the manager plans them as coflows under four policies
(FIFO, SEBF, max-min fair, weighted-fair queuing) and reports mean
coflow-completion time and makespan.  For the full service-integrated path —
tenants submitting into a cluster's admission queue and `run_pending()`
executing the scheduled order — see ``examples/multitenant.py``.

    PYTHONPATH=src python examples/coscheduling.py
"""
import numpy as np

from repro.core import HASH_PART, CoflowRequest, CoflowScheduler, Msgs, datacenter


def make_request(tenant, stage, nw, n_msgs, seed, weight=1.0):
    rng = np.random.default_rng(seed)
    bufs = {w: Msgs(rng.integers(0, 4096, n_msgs), rng.random((n_msgs, 1)))
            for w in range(nw)}
    return CoflowRequest(tenant, stage, bufs, HASH_PART, weight=weight)


def main() -> None:
    topo = datacenter(4, 5, 2, oversubscription=4.0)
    nw = topo.num_workers
    requests = [
        make_request("spark-etl", "stage-7", nw, 40_000, seed=1),      # big
        make_request("pregel-pr", "superstep-3", nw, 6_000, seed=2),   # medium
        make_request("adhoc-sql", "join-1", nw, 800, seed=3, weight=2.0),  # small, prioritized
    ]
    for policy in ("fifo", "sebf", "fair", "wfair"):
        sched = CoflowScheduler(topo, policy)
        plan = sched.plan(requests)
        print(f"[{policy}]  mean CCT {sched.mean_cct(plan)*1e3:7.2f} ms   "
              f"makespan {sched.makespan(plan)*1e3:7.2f} ms")
        for e in plan:
            print(f"    {e.coflow_id[0]:10s}/{e.coflow_id[1]:12s} "
                  f"start {e.start*1e3:7.2f} ms  finish {e.finish*1e3:7.2f} ms"
                  f"  share {e.share:.2f}")


if __name__ == "__main__":
    main()
