"""Continuous ingest: the streaming shuffle's feed()/drain() API.

A barrier shuffle needs the whole input up front — an open-ended source (an
event stream, a log tail, a training-data pipe) has no "whole input", so the
barrier model simply has no answer for it.  This example drives the streaming
execution model's native path instead: ``open_stream()`` returns a session,
every batch the source produces is ``feed()``-ed as it arrives (partitioned,
charged as chunked sub-epochs, and *incrementally combined* into bounded
per-destination accumulators), and ``drain()`` closes the stream and returns
the combined result.

Along the way it prints what makes the streaming model tick: the accumulator
stays O(distinct keys) no matter how much data flowed, and the one-shot
comparison at the end shows the chunk-pipelined modelled time beating the
barrier on the same total workload.

    PYTHONPATH=src python examples/stream_ingest.py
"""
import numpy as np

from repro.core import SUM, Msgs, TeShuService, datacenter


def event_source(nw: int, ticks: int, per_tick: int, seed: int = 0):
    """A synthetic open-ended source: Zipf-keyed events arriving in batches
    (think per-minute aggregation windows landing on ingest workers)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, 5001, dtype=np.float64)
    cdf = np.cumsum(ranks ** -1.1) / np.sum(ranks ** -1.1)
    for _ in range(ticks):
        yield {w: Msgs(np.searchsorted(cdf, rng.random(per_tick)).astype(np.int64),
                       np.ones((per_tick, 1)))
               for w in range(nw)}


def main() -> None:
    topo = datacenter(workers_per_server=4, servers_per_rack=2, racks=2,
                      oversubscription=8.0)
    nw = topo.num_workers
    svc = TeShuService(topo, streaming="auto", chunk_bytes=16 * 1024)
    print(f"topology: {nw} workers, boundaries "
          f"{[lv.name for lv in topo.levels]}\n")

    ticks, per_tick = 8, 20_000
    print(f"[ingest] {ticks} ticks x {per_tick} events/worker, "
          f"counting events per key (comb_fn=SUM)")
    sess = svc.open_stream("vanilla_push", list(range(nw)), list(range(nw)),
                           comb_fn=SUM)
    rows_in = 0
    for t, batch in enumerate(event_source(nw, ticks, per_tick)):
        sess.feed(batch)
        rows_in += sum(m.n for m in batch.values())
        acc_rows = sum(m.n for m in sess.acc.values() if m is not None)
        print(f"   tick {t}: {rows_in:>9,} events in | accumulator holds "
              f"{acc_rows:>6,} combined rows | {sess.chunks_fed:>4} chunks")

    out = sess.drain()
    total = sum(m.vals.sum() for m in out["bufs"].values())
    hottest = max((int(m.vals.max()) for m in out["bufs"].values() if m.n),
                  default=0)
    st = out["stats"]
    print(f"\n[drain] {out['chunks']} chunks, {out['rows']:,} events -> "
          f"{sum(m.n for m in out['bufs'].values()):,} keys "
          f"(conservation: {int(total):,} counted)")
    print(f"   hottest key: {hottest:,} events")
    print(f"   bytes moved {st['total_bytes']/1e6:.1f} MB, modelled time "
          f"{st['modelled_time_s']*1e3:.2f} ms (chunk-pipelined)\n")

    # the same total workload as one shuffle, barrier vs streamed
    merged = {w: Msgs.concat([b[w] for b in event_source(nw, ticks, per_tick)])
              for w in range(nw)}
    print("[one-shot] same events as a single shuffle, both execution models")
    for mode in ("off", "auto"):
        one = TeShuService(topo, streaming=mode, chunk_bytes=16 * 1024)
        one.shuffle("vanilla_push", {w: m.copy() for w, m in merged.items()},
                    list(range(nw)), list(range(nw)), comb_fn=SUM)
        one.reset_stats()
        res = one.shuffle("vanilla_push",
                          {w: m.copy() for w, m in merged.items()},
                          list(range(nw)), list(range(nw)), comb_fn=SUM)
        label = "pipelined" if res.streamed else "barrier  "
        print(f"   {label} modelled "
              f"{one.stats()['modelled_time_s']*1e3:8.3f} ms")


if __name__ == "__main__":
    main()
