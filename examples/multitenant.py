"""Multi-tenant shuffle-as-a-service: one cluster, many applications.

Three applications share one :class:`TeShuCluster`: a Spark-like ETL job
(big, uniform), a Pregel job (medium, skewed), and an ad-hoc SQL tenant
(small, prioritized).  The tour shows

1. per-tenant handles with private plan-cache namespaces (the ETL tenant's
   iterative workload hits its own cache; the others stay cold),
2. tenant-tagged ledger lanes and journal records, and
3. the admission queue: the same three submissions run FIFO vs weighted-fair,
   and the realized mean coflow-completion time is compared.

    PYTHONPATH=src python examples/multitenant.py
"""
import numpy as np

from repro.core import SUM, Msgs, TeShuCluster, datacenter


def make_bufs(nw, n, keys, alpha, seed):
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, keys + 1, dtype=np.float64)
    w = ranks ** -alpha if alpha > 0 else np.ones(keys)
    cdf = np.cumsum(w) / np.sum(w)
    return {wid: Msgs(np.searchsorted(cdf, rng.random(n)).astype(np.int64),
                      rng.random((n, 1)))
            for wid in range(nw)}


def main() -> None:
    topo = datacenter(4, 2, 2, oversubscription=4.0)
    nw = topo.num_workers
    workers = list(range(nw))

    cluster = TeShuCluster(topo, admission="wfair")
    etl = cluster.tenant("spark-etl", quota=32)
    pregel = cluster.tenant("pregel-pr")
    adhoc = cluster.tenant("adhoc-sql", priority=2.0)

    # --- direct calls: isolation without ceremony --------------------------
    print("== direct shuffles, private plan caches ==")
    for _ in range(3):                     # iterative: superstep after superstep
        etl.shuffle("network_aware", make_bufs(nw, 6_000, 4096, 0.0, 1),
                    workers, workers, comb_fn=SUM)
    pregel.shuffle("network_aware", make_bufs(nw, 2_000, 512, 1.2, 2),
                   workers, workers, comb_fn=SUM)
    for t in (etl, pregel, adhoc):
        cs = t.cache_stats()
        print(f"  {t.tenant_id:10s} cache hits={cs['hits']} "
              f"misses={cs['misses']} size={cs['size']}  "
              f"lane={t.stats()['bytes'] / 1e6:7.2f} MB")

    # --- admission: FIFO vs weighted-fair ----------------------------------
    print("\n== admission queue: big ETL submits first ==")
    for policy in ("fifo", "wfair"):
        cl = TeShuCluster(topo, admission=policy)
        t_etl = cl.tenant("spark-etl")
        t_pre = cl.tenant("pregel-pr")
        t_ad = cl.tenant("adhoc-sql", priority=2.0)
        t_etl.submit("vanilla_push", make_bufs(nw, 40_000, 4096, 0.0, 3),
                     workers, workers, comb_fn=SUM, stage="stage-7")
        t_pre.submit("vanilla_push", make_bufs(nw, 6_000, 512, 1.2, 4),
                     workers, workers, comb_fn=SUM, stage="superstep-3")
        t_ad.submit("vanilla_push", make_bufs(nw, 800, 2048, 0.0, 5),
                    workers, workers, comb_fn=SUM, stage="join-1")
        cl.run_pending()
        sched = cl.last_schedule()
        print(f"  [{policy}]  mean CCT {sched['mean_cct_s'] * 1e3:7.3f} ms   "
              f"makespan {sched['makespan_s'] * 1e3:7.3f} ms")
        for (tenant, stage), cct in sorted(sched["ccts"].items(),
                                           key=lambda kv: kv[1]):
            print(f"      {tenant:10s}/{stage:12s} done at "
                  f"{cct * 1e3:7.3f} ms")


if __name__ == "__main__":
    main()
