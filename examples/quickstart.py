"""Quickstart: the TeShu shuffle service in 60 seconds.

Builds a paper-shaped datacenter topology (2 racks, oversubscribed 10:1), runs
the same skewed shuffle through the vanilla and the network-aware templates,
and prints the bytes each one pushed across every network boundary plus the
adaptive EFF/COST decisions — the core of the paper in one screen.  A final
section repeats the adaptive shuffle to show the plan cache kicking in:
instantiation (sampling + EFF/COST rendezvous) is skipped and execution moves
to the batched data plane.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import SUM, Msgs, TeShuService, datacenter


def main() -> None:
    topo = datacenter(workers_per_server=4, servers_per_rack=5, racks=2,
                      oversubscription=10.0)
    svc = TeShuService(topo)
    nw = topo.num_workers
    print(f"topology: {nw} workers, boundaries "
          f"{[lv.name for lv in topo.levels]}, oversubscription 10:1\n")

    # a skewed workload: power-law keys (think PageRank messages per vertex)
    rng = np.random.default_rng(0)
    ranks = np.arange(1, 20001, dtype=np.float64)
    cdf = np.cumsum(ranks ** -0.9) / np.sum(ranks ** -0.9)
    bufs = {w: Msgs(np.searchsorted(cdf, rng.random(50_000)).astype(np.int64),
                    rng.random((50_000, 1))) for w in range(nw)}

    for template in ("vanilla_push", "network_aware"):
        svc.reset_stats()
        res = svc.shuffle(template,
                          {w: m.copy() for w, m in bufs.items()},
                          list(range(nw)), list(range(nw)),
                          comb_fn=SUM, rate=0.01)
        st = svc.stats()
        print(f"[{template}]")
        for name, b in st["bytes_per_level"].items():
            print(f"   {name:7s} {b/1e6:10.2f} MB")
        print(f"   modelled completion {st['modelled_time_s']*1e3:8.1f} ms"
              f"   sample overhead {st['sample_bytes']/1e6:.3f} MB")
        if res.decisions:
            for level, ec in res.decisions:
                verdict = "DO" if ec.beneficial else "skip"
                print(f"   decision @{level}: EFF={ec.eff*1e3:.2f}ms "
                      f"COST={ec.cost*1e3:.2f}ms r̂={ec.reduction_ratio:.3f} "
                      f"-> {verdict}")
        print()

    # iterative workloads (supersteps, training steps) repeat the same shuffle:
    # the plan cache replays the frozen instantiation on the batched data plane
    print("[plan cache] repeating the network_aware shuffle 3x")
    for i in range(3):
        t0 = time.perf_counter()
        res = svc.shuffle("network_aware",
                          {w: m.copy() for w, m in bufs.items()},
                          list(range(nw)), list(range(nw)),
                          comb_fn=SUM, rate=0.01)
        dt = (time.perf_counter() - t0) * 1e3
        how = "vectorized replay" if res.vectorized else "fresh instantiation"
        print(f"   run {i}: {dt:7.1f} ms wall ({how})")
    print(f"   cache stats: {svc.cache_stats()}")


if __name__ == "__main__":
    main()
