"""End-to-end graph analytics (the paper's own workload class).

Runs PageRank and SSSP over an R-MAT power-law graph through the Pregel engine
whose per-superstep message exchange is a TeShu shuffle, comparing vanilla vs
network-aware shuffling at several oversubscription ratios — a container-scale
Table 4.

    PYTHONPATH=src python examples/graph_analytics.py [--edges 200000]
"""
import argparse
import time

from repro.apps.graph.engine import PregelEngine, rmat_graph
from repro.apps.graph.programs import PageRank, SSSP
from repro.core import TeShuService, datacenter


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=8192)
    ap.add_argument("--edges", type=int, default=120_000)
    ap.add_argument("--supersteps", type=int, default=5)
    args = ap.parse_args()

    g = rmat_graph(args.vertices, args.edges, seed=7)
    print(f"graph: {g.num_vertices} vertices, {g.num_edges} edges (R-MAT)\n")

    header = f"{'ratio':>6s} {'workload':>9s} {'template':>14s} " \
             f"{'global MB':>10s} {'modelled ms':>12s} {'decisions':>10s}"
    print(header)
    for ratio in (10.0, 4.0, 1.0):
        for name, prog in (("PageRank", PageRank(args.supersteps)),
                           ("SSSP", SSSP(0, args.supersteps))):
            base = {}
            for template in ("vanilla_push", "network_aware"):
                topo = datacenter(4, 5, 2, oversubscription=ratio)
                svc = TeShuService(topo)
                eng = PregelEngine(g, svc, template_id=template, rate=0.01)
                t0 = time.time()
                eng.run(prog)
                st = svc.stats()
                dec = ""
                if template == "network_aware" and eng.decisions:
                    first = next((d for d in eng.decisions if d), [])
                    dec = ",".join(
                        {"server": "S", "rack": "R"}[lv]
                        for lv, ec in first if ec.beneficial) + ",G"
                print(f"{ratio:5.0f}:1 {name:>9s} {template:>14s} "
                      f"{st['bytes_per_level']['global']/1e6:10.2f} "
                      f"{st['modelled_time_s']*1e3:12.1f} {dec:>10s}")
                base[template] = st["modelled_time_s"]
            sp = base["vanilla_push"] / base["network_aware"]
            print(f"{'':>32s} -> modelled speedup {sp:4.1f}x\n")


if __name__ == "__main__":
    main()
