"""End-to-end LM training driver (deliverable b): ~100M-parameter model,
a few hundred steps, full production loop — data pipeline with prefetch,
AdamW + cosine schedule, grad accumulation, async atomic checkpoints,
restart-on-relaunch, and shuffle-manager step records.

Container defaults keep one CPU core busy for a few minutes; pass
--d-model 768 --layers 12 --steps 300 for the full ~100M/300-step run.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--moe]
"""
import argparse
import dataclasses

import jax

from repro.launch.train import train
from repro.models.config import ModelConfig, MoEConfig
import repro.configs as configs


def build_config(args) -> ModelConfig:
    moe = None
    if args.moe:
        moe = MoEConfig(num_experts=8, num_shared=1, top_k=2,
                        d_ff_expert=args.d_model * 2, capacity_factor=1.5,
                        dispatch="teshu")
    return ModelConfig(
        name=f"example-{args.d_model}d{args.layers}L",
        family="moe" if args.moe else "dense",
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 128),
        d_head=64,
        d_ff=args.d_model * 4,
        vocab=32_768,
        moe=moe,
        dtype="float32",
        remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--moe", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/teshu_train_ckpt")
    args = ap.parse_args()

    cfg = build_config(args)
    print(f"model: {cfg.name}, {cfg.num_params()/1e6:.1f}M params "
          f"({cfg.num_active_params()/1e6:.1f}M active), "
          f"{len(jax.devices())} device(s)")

    # register as a one-off arch so the shared driver can look it up
    module = type("cfgmod", (), {"CONFIG": cfg, "SMOKE": cfg})
    configs._MODULES[cfg.name] = cfg.name
    import sys
    sys.modules[f"repro.configs.{cfg.name}"] = module

    out = train(cfg.name, smoke=True, steps=args.steps,
                global_batch=args.batch, seq_len=args.seq,
                ckpt_dir=args.ckpt_dir, ckpt_every=25,
                n_micro=args.n_micro, lr=6e-4, log_every=5)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"\nloss: first={losses[0]:.4f} min={min(losses):.4f} "
              f"last={losses[-1]:.4f} over {len(losses)} steps")
        print("training", "improved" if losses[-1] < losses[0] else
              "did not improve", "(markov synthetic data)")
    # straggler/progress records from the shuffle manager
    mgr = out["manager"]
    print(f"manager: {len(mgr.records())} step records journaled")


if __name__ == "__main__":
    main()
