"""Batched serving with continuous batching (deliverable b, serving flavor).

Prefill a batch of prompts into a shared ring KV cache, decode in lockstep,
and swap finished rows for queued requests between steps — the standard
continuous-batching loop, here over the smoke config of any assigned arch.

    PYTHONPATH=src python examples/serve_lm.py --arch granite-34b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.mesh import elastic_mesh
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="granite-34b")
    ap.add_argument("--slots", type=int, default=4, help="batch slots")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    mesh = elastic_mesh(len(jax.devices()),
                        model_parallel=min(2, len(jax.devices())))
    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
             for _ in range(args.requests)]
    # per-request stop length (simulates varying generation lengths)
    stops = [int(rng.integers(4, args.max_new)) for _ in range(args.requests)]

    with mesh:
        params = lm.init_lm(jax.random.key(0), cfg)

        @jax.jit
        def prefill_one(params, cache, tokens, slot):
            """Refill one slot: write the prompt into rows [slot] of the cache."""
            logits, new_cache, _ = lm.forward(
                params, cfg, tokens=tokens, cache=cache)
            return logits[:, -1], new_cache

        @jax.jit
        def decode(params, cache, tok):
            logits, cache = lm.serve_step(params, cfg, cache, tokens=tok)
            return logits[:, -1], cache

        served, active, gen_count = 0, {}, {}
        outputs = {}
        t0 = time.time()
        steps = 0
        # NOTE container-scale simplification: one cache per wave; true
        # row-level swap needs per-slot cache surgery (out of scope here)
        while queue or active:
            free = args.slots - len(active)
            wave = []
            for _ in range(min(free, len(queue))):
                wave.append(queue.pop(0))
            if wave:
                batch = np.stack(wave + [wave[-1]] * (args.slots - len(wave) -
                                                      len(active)))[:args.slots]
                cache = lm.init_cache(cfg, batch.shape[0], args.max_len)
                logits, cache = prefill_one(params, cache,
                                            jnp.asarray(batch), 0)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                for i in range(len(wave)):
                    rid = served + i
                    active[rid] = i
                    gen_count[rid] = 0
                    outputs[rid] = []
            # decode until every active request hits its stop length
            while active:
                for rid in list(active):
                    outputs[rid].append(int(tok[active[rid], 0]))
                    gen_count[rid] += 1
                    if gen_count[rid] >= stops[rid]:
                        del active[rid]
                if not active:
                    break
                logits, cache = decode(params, cache, tok)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                steps += 1
            served += len(wave)
        dt = time.time() - t0

    total_tokens = sum(len(v) for v in outputs.values())
    print(f"served {served} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, {steps} decode steps)")
    for rid in sorted(outputs)[:3]:
        print(f"  req {rid}: {outputs[rid][:10]}{'...' if len(outputs[rid])>10 else ''}")


if __name__ == "__main__":
    main()
